//! THE core invariant (paper title: "Lossless Inference Acceleration"):
//! every engine's greedy output must equal plain autoregressive greedy
//! decoding, token-for-token, for every engine × category × seed.
//!
//! Hermetic: runs on the pure-Rust reference backend when no artifacts
//! exist (`Runtime::open` falls back automatically), and on PJRT when
//! `make artifacts` has run and the crate is built with `--features pjrt`.

use cas_spec::engine::{build_engine, EngineOpts, ENGINES};
use cas_spec::harness::{run_suite, run_suite_with};
use cas_spec::model::Variant;
use cas_spec::runtime::Runtime;
use cas_spec::spec::SamplingParams;
use cas_spec::workload::{Language, Suite};

fn open_runtime() -> Runtime {
    Runtime::open(&Runtime::default_dir()).expect("runtime open")
}

#[test]
fn all_engines_reproduce_ar_greedy() {
    let rt = open_runtime();
    let srt = rt.load_scale("small", &Variant::ALL).expect("load small");
    let lang = Language::build(rt.manifest.lang_seed);
    let suite = Suite::spec_bench(&lang, 7, 1, 24);
    let engines: Vec<String> = ENGINES.iter().map(|s| s.to_string()).collect();
    // run_suite with check_lossless=true fails on the first divergence
    run_suite(&srt, &suite, &engines, &EngineOpts::default(), true, false)
        .expect("losslessness violated");
}

#[test]
fn lossless_across_seeds_and_lengths() {
    let rt = open_runtime();
    let srt = rt.load_scale("small", &Variant::ALL).expect("load small");
    let lang = Language::build(rt.manifest.lang_seed);
    // the adaptive engine is the most state-heavy: sweep seeds on it
    let engines = vec!["cas-spec".to_string()];
    for (seed, max_new) in [(1u64, 17usize), (2, 40), (3, 9)] {
        let suite = Suite::spec_bench(&lang, seed, 1, max_new);
        run_suite(&srt, &suite, &engines, &EngineOpts::default(), true, false)
            .unwrap_or_else(|e| panic!("seed {seed} len {max_new}: {e:#}"));
    }
}

#[test]
fn quantized_engines_stay_lossless_across_seeds() {
    // The int8-activation drafts (aq8 / aq8ls40) propose through a
    // different numeric path than the target verifies with; losslessness
    // must hold anyway because verification is unchanged. Sweep the two
    // quantized engines — the ls60→aq8→target static cascade and the
    // quantized-pool DyTC — across seeds and lengths, greedy and sampled.
    let rt = open_runtime();
    let srt = rt.load_scale("small", &Variant::ALL).expect("load small");
    let lang = Language::build(rt.manifest.lang_seed);
    let engines = vec!["casc-aq".to_string(), "cas-spec-aq".to_string()];
    for (seed, max_new) in [(1u64, 18usize), (2, 33), (9, 10)] {
        let suite = Suite::spec_bench(&lang, seed, 1, max_new);
        run_suite(&srt, &suite, &engines, &EngineOpts::default(), true, false)
            .unwrap_or_else(|e| panic!("greedy seed {seed} len {max_new}: {e:#}"));
        let sp = SamplingParams { temperature: 0.8, top_p: 0.92, seed: seed * 71 };
        run_suite_with(
            &srt,
            &suite,
            &engines,
            &EngineOpts::default(),
            true,
            false,
            Some(sp),
        )
        .unwrap_or_else(|e| panic!("sampled seed {seed} len {max_new}: {e:#}"));
    }
}

#[test]
fn engine_state_reuse_stays_lossless() {
    // DyTC keeps estimator state across requests; repeated generates on the
    // same engine instance must stay lossless (run_suite reuses instances).
    let rt = open_runtime();
    let srt = rt.load_scale("small", &Variant::ALL).expect("load small");
    let lang = Language::build(rt.manifest.lang_seed);
    let suite = Suite::spec_bench(&lang, 11, 2, 16); // 2 prompts/category
    run_suite(
        &srt,
        &suite,
        &["cas-spec+".to_string()],
        &EngineOpts::default(),
        true,
        false,
    )
    .expect("stateful reuse violated losslessness");
}

#[test]
fn all_engines_reproduce_sampled_ar() {
    // Distribution-lossless sampled decoding: with temperature > 0 every
    // engine must still emit byte-identical transcripts to sampled AR for
    // the same seed, because verification couples each position's draw to
    // the target row through one position-keyed random stream.
    let rt = open_runtime();
    let srt = rt.load_scale("small", &Variant::ALL).expect("load small");
    let lang = Language::build(rt.manifest.lang_seed);
    let suite = Suite::spec_bench(&lang, 7, 1, 20);
    let engines: Vec<String> = ENGINES.iter().map(|s| s.to_string()).collect();
    let sp = SamplingParams { temperature: 0.7, top_p: 0.9, seed: 1234 };
    run_suite_with(&srt, &suite, &engines, &EngineOpts::default(), true, false, Some(sp))
        .expect("sampled losslessness violated");
}

/// Two-sample chi-square homogeneity: sampled-AR token frequencies vs a
/// speculative engine's, over DISJOINT seed ranges (sharing seeds would
/// make the arms equal trivially through the coupling). Only the last
/// token of each short generation is counted so draws are independent
/// across seeds. Accepting H0 here is the distribution-losslessness
/// claim, as opposed to the per-seed sequence equality asserted above.
#[test]
fn sampled_spec_matches_sampled_ar_distribution() {
    let rt = open_runtime();
    let srt = rt.load_scale("small", &Variant::ALL).expect("load small");
    let lang = Language::build(rt.manifest.lang_seed);
    let suite = Suite::spec_bench(&lang, 7, 1, 4);
    let prompt = suite.items[0].prompt.clone();

    const B: usize = 8; // project token ids onto mod-B buckets
    const N: u64 = 150; // generations per arm
    let mut counts = [[0f64; B]; 2];
    let arms = [("ar", 1_000u64), ("swift", 5_000u64)];
    for (arm, (engine, seed0)) in arms.into_iter().enumerate() {
        let mut eng = build_engine(engine, &srt, &EngineOpts::default()).expect("engine");
        for i in 0..N {
            // temperature > 1 flattens the rows so the test has power
            let sp = SamplingParams { temperature: 1.3, top_p: 1.0, seed: seed0 + i };
            let g = eng.generate_sampled(&prompt, 2, Some(sp)).expect("generate");
            let last = *g.tokens.last().expect("nonempty generation");
            counts[arm][last as usize % B] += 1.0;
        }
    }

    let n = N as f64;
    let total = 2.0 * n;
    let mut x2 = 0.0;
    let mut df = -1i32; // buckets - 1, counting only non-empty buckets
    for b in 0..B {
        let col = counts[0][b] + counts[1][b];
        if col == 0.0 {
            continue;
        }
        df += 1;
        for arm in 0..2 {
            let e = n * col / total;
            let d = counts[arm][b] - e;
            x2 += d * d / e;
        }
    }
    assert!(df >= 1, "degenerate bucketing");
    // Wilson-Hilferty 99.99% critical value (z = 3.719): the false-alarm
    // rate of this test is 1e-4, while a real distribution bug shifts X2
    // by O(N) and blows far past it.
    let d = df as f64;
    let crit = d * (1.0 - 2.0 / (9.0 * d) + 3.719 * (2.0 / (9.0 * d)).sqrt()).powi(3);
    assert!(
        x2 < crit,
        "sampled spec diverges from sampled AR: X2={x2:.2} >= crit={crit:.2} (df={df})"
    );
}

#[test]
fn nondefault_hyperparams_stay_lossless() {
    // Scheduling hyper-parameters must never affect WHAT is generated.
    let rt = open_runtime();
    let srt = rt.load_scale("small", &Variant::ALL).expect("load small");
    let lang = Language::build(rt.manifest.lang_seed);
    let suite = Suite::spec_bench(&lang, 5, 1, 20);
    for (k_max, t_min, draft_k) in [(1usize, 0.5f64, 2usize), (5, 2.0, 9)] {
        let mut opts = EngineOpts::default();
        opts.dytc.k_max = k_max;
        opts.dytc.t_min = t_min;
        opts.draft_k = draft_k;
        run_suite(
            &srt,
            &suite,
            &["cas-spec".to_string(), "swift".to_string(), "vchc".to_string()],
            &opts,
            true,
            false,
        )
        .unwrap_or_else(|e| panic!("k_max={k_max} t_min={t_min}: {e:#}"));
    }
}
