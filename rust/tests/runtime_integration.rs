//! Runtime-level integration, backend-agnostic: KV semantics
//! (write/commit/rollback), chain-vs-tree equivalence, and the draft
//! variants' parameter-subset sharing — exercised through whichever
//! backend `Runtime::open` selects (reference when artifacts are absent,
//! PJRT with artifacts + the `pjrt` feature).

use cas_spec::model::Variant;
use cas_spec::runtime::{argmax, Runtime, ScaleRuntime, VERIFY_T};
use cas_spec::spec::{DraftTree, VariantSession};

fn load() -> ScaleRuntime {
    let rt = Runtime::open(&Runtime::default_dir()).expect("runtime open");
    rt.load_scale("small", &Variant::ALL).expect("load small")
}

const PROMPT: [u32; 9] = [1, 30, 40, 50, 60, 70, 80, 90, 100];

#[test]
fn decode_deterministic() {
    let srt = load();
    let run = || -> anyhow::Result<Vec<u32>> {
        let mut s = VariantSession::new(&srt, Variant::Target)?;
        s.feed(&PROMPT)?;
        let mut out = vec![argmax(s.last_logits().unwrap())];
        for _ in 0..10 {
            let next = argmax(s.decode_one(*out.last().unwrap())?);
            out.push(next);
        }
        Ok(out)
    };
    assert_eq!(run().unwrap(), run().unwrap());
}

#[test]
fn chunked_prefill_equals_token_by_token() {
    let srt = load();
    // chunked feed
    let mut a = VariantSession::new(&srt, Variant::Target).unwrap();
    a.feed(&PROMPT).unwrap();
    // token-by-token decode feed
    let mut b = VariantSession::new(&srt, Variant::Target).unwrap();
    for &t in &PROMPT {
        b.decode_one(t).unwrap();
    }
    let la = a.last_logits().unwrap();
    let lb = b.last_logits().unwrap();
    assert_eq!(a.pos(), b.pos());
    for (x, y) in la.iter().zip(lb) {
        assert!((x - y).abs() < 2e-3, "prefill/decode mismatch: {x} vs {y}");
    }
    assert_eq!(argmax(la), argmax(lb));
}

#[test]
fn tree_verify_matches_sequential_decode() {
    let srt = load();
    // sequential: feed prompt then decode 3 tokens t1,t2,t3 greedily
    let mut s = VariantSession::new(&srt, Variant::Target).unwrap();
    s.feed(&PROMPT).unwrap();
    let t1 = argmax(s.last_logits().unwrap());
    let mut seq = vec![t1];
    for _ in 0..3 {
        let n = argmax(s.decode_one(*seq.last().unwrap()).unwrap());
        seq.push(n);
    }
    // tree: verify the chain [t1, t2, t3] in one step; all must be accepted
    let mut s2 = VariantSession::new(&srt, Variant::Target).unwrap();
    s2.feed(&PROMPT).unwrap();
    let tree = DraftTree::chain(seq[0], &seq[1..3], 8);
    let out = s2.verify_tree(&tree, 8).unwrap();
    let v = cas_spec::spec::verify_greedy(&tree, &out.logits, srt.vocab());
    assert_eq!(v.accepted_tokens, &seq[1..3], "greedy chain must fully accept");
    assert_eq!(v.bonus, seq[3]);
}

#[test]
fn commit_gather_equals_chain_replay() {
    let srt = load();
    // Build a branching tree where the accepted path is NOT slot-contiguous,
    // commit it, and check subsequent decoding equals a chain replay.
    let mut s = VariantSession::new(&srt, Variant::Target).unwrap();
    s.feed(&PROMPT).unwrap();
    let root = argmax(s.last_logits().unwrap());

    // find what the model actually continues with
    let mut probe = VariantSession::new(&srt, Variant::Target).unwrap();
    probe.feed(&PROMPT).unwrap();
    let t1 = argmax(probe.decode_one(root).unwrap());
    let t2 = argmax(probe.decode_one(t1).unwrap());
    let t3 = argmax(probe.decode_one(t2).unwrap());

    // tree: root -> wrong(1) ; root -> t1(2) -> t2(3); accepted = 0,2,3
    let mut tree = DraftTree::new(root, VERIFY_T);
    tree.add_child(0, t1.wrapping_add(1) % 512, 0.1, 0, 0.1); // wrong branch
    let a = tree.add_child(0, t1, 0.9, 0, 0.9);
    tree.add_child(a, t2, 0.9, 0, 0.8);
    let out = s.verify_tree(&tree, VERIFY_T).unwrap();
    let v = cas_spec::spec::verify_greedy(&tree, &out.logits, srt.vocab());
    assert_eq!(v.accepted_slots, vec![0, 2, 3]);
    assert_eq!(v.bonus, t3);
    s.commit_slots(VERIFY_T, &v.accepted_slots).unwrap();
    let vocab = srt.vocab();
    let last = *v.accepted_slots.last().unwrap();
    s.set_last_logits(&out.logits[last * vocab..(last + 1) * vocab]);

    // after gather-commit, decoding t3 must match the replay path
    let t4_tree = argmax(s.decode_one(t3).unwrap());
    let t4_chain = argmax(probe.decode_one(t3).unwrap());
    assert_eq!(t4_tree, t4_chain, "gather-commit corrupted the KV cache");
}

#[test]
fn rollback_discards_speculation() {
    let srt = load();
    let mut s = VariantSession::new(&srt, Variant::Target).unwrap();
    s.feed(&PROMPT).unwrap();
    let pos0 = s.pos();
    let next0 = argmax(s.last_logits().unwrap());
    // speculate a garbage chain, then roll back
    s.decode_one(next0).unwrap();
    s.decode_one(17).unwrap();
    s.decode_one(200).unwrap();
    s.rollback(pos0);
    // decoding the same token again must give the original distribution
    let l = s.decode_one(next0).unwrap().to_vec();
    let mut fresh = VariantSession::new(&srt, Variant::Target).unwrap();
    fresh.feed(&PROMPT).unwrap();
    let lf = fresh.decode_one(next0).unwrap();
    for (x, y) in l.iter().zip(lf) {
        assert!((x - y).abs() < 2e-3);
    }
}

#[test]
fn draft_variants_run_and_differ_from_target() {
    let srt = load();
    let mut logits: Vec<Vec<f32>> = Vec::new();
    for v in Variant::ALL {
        let mut s = VariantSession::new(&srt, v).unwrap();
        s.feed(&PROMPT).unwrap();
        logits.push(s.last_logits().unwrap().to_vec());
    }
    // all variants produce finite logits; drafts differ from the target
    for l in &logits {
        assert!(l.iter().all(|x| x.is_finite()));
    }
    for i in 1..logits.len() {
        assert_ne!(logits[0], logits[i], "draft {i} identical to target");
    }
}

#[test]
fn counters_track_execution() {
    let srt = load();
    srt.reset_counters();
    let mut s = VariantSession::new(&srt, Variant::Ls60).unwrap();
    s.feed(&PROMPT).unwrap();
    s.decode_one(40).unwrap();
    let c = srt.counters(Variant::Ls60);
    assert!(c.steps >= 2);
    assert!(c.time.as_nanos() > 0);
    assert_eq!(srt.counters(Variant::Ls40).steps, 0);
}
