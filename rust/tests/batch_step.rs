//! Bit-exactness of the batched step path (`Backend::step_batch`).
//!
//! Two independent guarantees are pinned here:
//!
//!   1. the trait's **default implementation** (loop per lane — what the
//!      PJRT backend uses) matches per-lane `step` calls bit-for-bit;
//!   2. the reference backend's **overridden** genuinely-batched forward
//!      (layer-outer, lane-inner, shared weight reads) also matches
//!      per-lane `step` bit-for-bit, across mixed variants, positions and
//!      live counts.
//!
//! Bit-exactness here is what makes greedy losslessness survive
//! continuous batching without any per-engine re-proof.

use std::path::Path;

use anyhow::Result;
use cas_spec::model::{ScaleInfo, Variant};
use cas_spec::runtime::reference::RefBackend;
use cas_spec::runtime::{Backend, BackendSelect, BatchLane, KvState, LaneStep, Runtime};
use cas_spec::spec::DraftTree;

fn backend() -> RefBackend {
    let info = ScaleInfo::synthetic("small", 6, 128, 4);
    RefBackend::new(&info, &Variant::ALL, None).unwrap()
}

fn host(kv: &KvState) -> &[f32] {
    match kv {
        KvState::Host(c) => c,
        #[cfg(feature = "pjrt")]
        _ => panic!("expected a host cache"),
    }
}

fn chain_inputs(tokens: &[u32], t_shape: usize) -> (Vec<u32>, Vec<f32>, Vec<i32>) {
    let tree = DraftTree::chain(tokens[0], &tokens[1..], t_shape);
    tree.serialize(t_shape, 0)
}

/// Wraps a backend, forwarding everything EXCEPT `step_batch`, so calls
/// exercise the trait's default per-lane loop implementation.
struct DefaultBatch<'a>(&'a RefBackend);

impl Backend for DefaultBatch<'_> {
    fn name(&self) -> &'static str {
        "default-batch"
    }

    fn variants(&self) -> Vec<Variant> {
        self.0.variants()
    }

    fn new_kv(&self, v: Variant) -> Result<KvState> {
        self.0.new_kv(v)
    }

    #[allow(clippy::too_many_arguments)]
    fn step(
        &self,
        v: Variant,
        kv: &mut KvState,
        pos: usize,
        t_shape: usize,
        live: usize,
        tokens: &[u32],
        mask: &[f32],
        depths: &[i32],
    ) -> Result<Vec<f32>> {
        self.0.step(v, kv, pos, t_shape, live, tokens, mask, depths)
    }

    fn gather_commit(
        &self,
        v: Variant,
        kv: &mut KvState,
        t_shape: usize,
        src_abs: &[usize],
        dst_pos: usize,
    ) -> Result<()> {
        self.0.gather_commit(v, kv, t_shape, src_abs, dst_pos)
    }
    // step_batch intentionally NOT overridden: the default impl runs
}

/// Each lane: (variant, committed-prefix tokens, stepped chain tokens).
/// The prefix is fed with a first step so lanes sit at different `pos`.
type LaneSpec = (Variant, Vec<u32>, Vec<u32>);

fn lane_specs() -> Vec<LaneSpec> {
    vec![
        (Variant::Target, vec![1, 30], vec![40, 50, 60]),
        (Variant::Ls40, vec![2], vec![31, 41]),
        (Variant::Target, vec![], vec![5, 33, 44, 55]),
        (Variant::Ee, vec![3, 32, 42], vec![52]),
    ]
}

/// Run each lane's prefix step solo (both paths share this setup), then
/// compare a batched second step against solo second steps.
fn assert_batch_matches_solo(be: &dyn Backend, label: &str) {
    let specs = lane_specs();
    let t_shape = 8;

    // ---- solo path: prefix step, then the compared step ----
    let mut solo_logits = Vec::new();
    let mut solo_caches = Vec::new();
    for (v, prefix, chain) in &specs {
        let mut kv = be.new_kv(*v).unwrap();
        if !prefix.is_empty() {
            let (tk, mk, dp) = chain_inputs(prefix, t_shape);
            be.step(*v, &mut kv, 0, t_shape, prefix.len(), &tk, &mk, &dp).unwrap();
        }
        let (tk, mk, dp) = chain_inputs(chain, t_shape);
        let lg = be
            .step(*v, &mut kv, prefix.len(), t_shape, chain.len(), &tk, &mk, &dp)
            .unwrap();
        solo_logits.push(lg);
        solo_caches.push(host(&kv).to_vec());
    }

    // ---- batched path: same prefixes, then ONE step_batch call ----
    let mut kvs: Vec<KvState> = Vec::new();
    for (v, prefix, _) in &specs {
        let mut kv = be.new_kv(*v).unwrap();
        if !prefix.is_empty() {
            let (tk, mk, dp) = chain_inputs(prefix, t_shape);
            be.step(*v, &mut kv, 0, t_shape, prefix.len(), &tk, &mk, &dp).unwrap();
        }
        kvs.push(kv);
    }
    let inputs: Vec<(Vec<u32>, Vec<f32>, Vec<i32>)> =
        specs.iter().map(|(_, _, chain)| chain_inputs(chain, t_shape)).collect();
    let mut lanes: Vec<LaneStep<'_>> = kvs
        .iter_mut()
        .zip(specs.iter())
        .zip(inputs.iter())
        .map(|((kv, (v, prefix, chain)), (tk, mk, dp))| LaneStep {
            variant: *v,
            kv,
            pos: prefix.len(),
            live: chain.len(),
            tokens: tk,
            mask: mk,
            depths: dp,
        })
        .collect();
    let batched = be.step_batch(t_shape, &mut lanes).unwrap();
    drop(lanes);

    assert_eq!(batched.len(), specs.len(), "{label}: one logits block per lane");
    for i in 0..specs.len() {
        assert_eq!(batched[i], solo_logits[i], "{label}: lane {i} logits diverged");
        assert_eq!(host(&kvs[i]), &solo_caches[i][..], "{label}: lane {i} KV diverged");
    }
}

#[test]
fn default_step_batch_matches_per_lane_step() {
    let be = backend();
    let wrapped = DefaultBatch(&be);
    assert_batch_matches_solo(&wrapped, "default impl");
}

#[test]
fn ref_step_batch_matches_per_lane_step() {
    let be = backend();
    assert_batch_matches_solo(&be, "ref override");
}

#[test]
fn ref_and_default_batch_agree() {
    // the fused forward and the naive per-lane loop produce byte-identical
    // logits AND KV caches on identical lane sets
    let be = backend();
    let specs = lane_specs();
    let t_shape = 8;
    let mut results: Vec<(Vec<Vec<f32>>, Vec<Vec<f32>>)> = Vec::new();
    for path in 0..2 {
        let mut kvs: Vec<KvState> =
            specs.iter().map(|(v, _, _)| be.new_kv(*v).unwrap()).collect();
        let inputs: Vec<(Vec<u32>, Vec<f32>, Vec<i32>)> = specs
            .iter()
            .map(|(_, _, chain)| chain_inputs(chain, t_shape))
            .collect();
        let mut lanes: Vec<LaneStep<'_>> = kvs
            .iter_mut()
            .zip(specs.iter())
            .zip(inputs.iter())
            .map(|((kv, (v, _, chain)), (tk, mk, dp))| LaneStep {
                variant: *v,
                kv,
                pos: 0,
                live: chain.len(),
                tokens: tk,
                mask: mk,
                depths: dp,
            })
            .collect();
        let out = if path == 0 {
            be.step_batch(t_shape, &mut lanes).unwrap()
        } else {
            DefaultBatch(&be).step_batch(t_shape, &mut lanes).unwrap()
        };
        drop(lanes);
        let caches: Vec<Vec<f32>> = kvs.iter().map(|kv| host(kv).to_vec()).collect();
        results.push((out, caches));
    }
    assert_eq!(results[0].0, results[1].0, "logits differ between paths");
    assert_eq!(results[0].1, results[1].1, "KV caches differ between paths");
}

#[test]
fn scale_runtime_step_batch_counts_lanes() {
    // the generic-layer wrapper: per-lane StepOutputs, per-variant counters
    let rt = Runtime::open_with(Path::new("/missing-artifacts"), BackendSelect::Ref)
        .expect("ref runtime");
    let srt = rt.load_scale("small", &[Variant::Target, Variant::Ls40]).unwrap();

    let mut kv_a = srt.new_kv(Variant::Target).unwrap();
    let mut kv_b = srt.new_kv(Variant::Ls40).unwrap();
    let (tk_a, mk_a, dp_a) = chain_inputs(&[1, 30, 40], 8);
    let (tk_b, mk_b, dp_b) = chain_inputs(&[2, 31], 8);
    let mut lanes = vec![
        BatchLane { kv: &mut kv_a, live: 3, tokens: tk_a, mask: mk_a, depths: dp_a },
        BatchLane { kv: &mut kv_b, live: 2, tokens: tk_b, mask: mk_b, depths: dp_b },
    ];
    let outs = srt.step_batch(8, &mut lanes).unwrap();
    drop(lanes);
    assert_eq!(outs.len(), 2);
    assert_eq!(outs[0].logits.len(), 8 * srt.vocab());
    assert_eq!(outs[1].logits.len(), 8 * srt.vocab());
    // positions are NOT advanced by a step (commit does that)
    assert_eq!(kv_a.pos, 0);
    assert_eq!(kv_b.pos, 0);
    assert_eq!(srt.counters(Variant::Target).steps, 1);
    assert_eq!(srt.counters(Variant::Target).tokens_stepped, 3);
    assert_eq!(srt.counters(Variant::Ls40).steps, 1);
    assert_eq!(srt.counters(Variant::Ls40).tokens_stepped, 2);
}
