//! Bit-exactness of the batched step path (`Backend::step_batch`).
//!
//! Four independent guarantees are pinned here:
//!
//!   1. the trait's **default implementation** (loop per lane — what the
//!      PJRT backend uses) matches per-lane `step` calls bit-for-bit;
//!   2. the reference backend's **overridden** genuinely-batched forward
//!      (layer-outer, lane-inner, shared weight reads) also matches
//!      per-lane `step` bit-for-bit, across mixed variants, positions and
//!      live counts;
//!   3. **threading is bit-neutral**: `threads=4` generations are
//!      byte-identical to `threads=1` for all 12 engines (lane-parallel
//!      and head-parallel paths);
//!   4. **lock-step fusion is bit-neutral**: driving concurrent runs
//!      through `begin_round`/`take_lane`/fused `step_batch`/
//!      `finish_round` — the server scheduler's cycle, at the padded
//!      group shape — reproduces `generate()` exactly.
//!
//! Bit-exactness here is what makes greedy losslessness survive
//! continuous batching, threading, and lane fusion without any
//! per-engine re-proof.

use std::path::Path;

use anyhow::Result;
use cas_spec::engine::{build_engine, EngineOpts, RequestRun, RoundPhase, ENGINES};
use cas_spec::model::{ScaleInfo, Variant};
use cas_spec::runtime::reference::RefBackend;
use cas_spec::runtime::{
    Backend, BackendSelect, BatchLane, KvState, LaneStep, Runtime, ScaleRuntime,
};
use cas_spec::spec::DraftTree;

fn backend() -> RefBackend {
    let info = ScaleInfo::synthetic("small", 6, 128, 4);
    RefBackend::new(&info, &Variant::ALL, None).unwrap()
}

fn host(kv: &KvState) -> &[f32] {
    match kv {
        KvState::Host(c) => c,
        #[cfg(feature = "pjrt")]
        _ => panic!("expected a host cache"),
    }
}

fn chain_inputs(tokens: &[u32], t_shape: usize) -> (Vec<u32>, Vec<f32>, Vec<i32>) {
    let tree = DraftTree::chain(tokens[0], &tokens[1..], t_shape);
    tree.serialize(t_shape, 0)
}

/// Wraps a backend, forwarding everything EXCEPT `step_batch`, so calls
/// exercise the trait's default per-lane loop implementation.
struct DefaultBatch<'a>(&'a RefBackend);

impl Backend for DefaultBatch<'_> {
    fn name(&self) -> &'static str {
        "default-batch"
    }

    fn variants(&self) -> Vec<Variant> {
        self.0.variants()
    }

    fn new_kv(&self, v: Variant) -> Result<KvState> {
        self.0.new_kv(v)
    }

    #[allow(clippy::too_many_arguments)]
    fn step(
        &self,
        v: Variant,
        kv: &mut KvState,
        pos: usize,
        t_shape: usize,
        live: usize,
        tokens: &[u32],
        mask: &[f32],
        depths: &[i32],
    ) -> Result<Vec<f32>> {
        self.0.step(v, kv, pos, t_shape, live, tokens, mask, depths)
    }

    fn gather_commit(
        &self,
        v: Variant,
        kv: &mut KvState,
        t_shape: usize,
        src_abs: &[usize],
        dst_pos: usize,
    ) -> Result<()> {
        self.0.gather_commit(v, kv, t_shape, src_abs, dst_pos)
    }
    // step_batch intentionally NOT overridden: the default impl runs
}

/// Each lane: (variant, committed-prefix tokens, stepped chain tokens).
/// The prefix is fed with a first step so lanes sit at different `pos`.
type LaneSpec = (Variant, Vec<u32>, Vec<u32>);

fn lane_specs() -> Vec<LaneSpec> {
    vec![
        (Variant::Target, vec![1, 30], vec![40, 50, 60]),
        (Variant::Ls40, vec![2], vec![31, 41]),
        (Variant::Target, vec![], vec![5, 33, 44, 55]),
        (Variant::Ee, vec![3, 32, 42], vec![52]),
    ]
}

/// Run each lane's prefix step solo (both paths share this setup), then
/// compare a batched second step against solo second steps.
fn assert_batch_matches_solo(be: &dyn Backend, label: &str) {
    let specs = lane_specs();
    let t_shape = 8;

    // ---- solo path: prefix step, then the compared step ----
    let mut solo_logits = Vec::new();
    let mut solo_caches = Vec::new();
    for (v, prefix, chain) in &specs {
        let mut kv = be.new_kv(*v).unwrap();
        if !prefix.is_empty() {
            let (tk, mk, dp) = chain_inputs(prefix, t_shape);
            be.step(*v, &mut kv, 0, t_shape, prefix.len(), &tk, &mk, &dp).unwrap();
        }
        let (tk, mk, dp) = chain_inputs(chain, t_shape);
        let lg = be
            .step(*v, &mut kv, prefix.len(), t_shape, chain.len(), &tk, &mk, &dp)
            .unwrap();
        solo_logits.push(lg);
        solo_caches.push(host(&kv).to_vec());
    }

    // ---- batched path: same prefixes, then ONE step_batch call ----
    let mut kvs: Vec<KvState> = Vec::new();
    for (v, prefix, _) in &specs {
        let mut kv = be.new_kv(*v).unwrap();
        if !prefix.is_empty() {
            let (tk, mk, dp) = chain_inputs(prefix, t_shape);
            be.step(*v, &mut kv, 0, t_shape, prefix.len(), &tk, &mk, &dp).unwrap();
        }
        kvs.push(kv);
    }
    let inputs: Vec<(Vec<u32>, Vec<f32>, Vec<i32>)> =
        specs.iter().map(|(_, _, chain)| chain_inputs(chain, t_shape)).collect();
    let mut lanes: Vec<LaneStep<'_>> = kvs
        .iter_mut()
        .zip(specs.iter())
        .zip(inputs.iter())
        .map(|((kv, (v, prefix, chain)), (tk, mk, dp))| LaneStep {
            variant: *v,
            kv,
            pos: prefix.len(),
            live: chain.len(),
            tokens: tk,
            mask: mk,
            depths: dp,
        })
        .collect();
    let batched = be.step_batch(t_shape, &mut lanes).unwrap();
    drop(lanes);

    assert_eq!(batched.len(), specs.len(), "{label}: one logits block per lane");
    for i in 0..specs.len() {
        assert_eq!(batched[i], solo_logits[i], "{label}: lane {i} logits diverged");
        assert_eq!(host(&kvs[i]), &solo_caches[i][..], "{label}: lane {i} KV diverged");
    }
}

#[test]
fn default_step_batch_matches_per_lane_step() {
    let be = backend();
    let wrapped = DefaultBatch(&be);
    assert_batch_matches_solo(&wrapped, "default impl");
}

#[test]
fn ref_step_batch_matches_per_lane_step() {
    let be = backend();
    assert_batch_matches_solo(&be, "ref override");
}

#[test]
fn ref_and_default_batch_agree() {
    // the fused forward and the naive per-lane loop produce byte-identical
    // logits AND KV caches on identical lane sets
    let be = backend();
    let specs = lane_specs();
    let t_shape = 8;
    let mut results: Vec<(Vec<Vec<f32>>, Vec<Vec<f32>>)> = Vec::new();
    for path in 0..2 {
        let mut kvs: Vec<KvState> =
            specs.iter().map(|(v, _, _)| be.new_kv(*v).unwrap()).collect();
        let inputs: Vec<(Vec<u32>, Vec<f32>, Vec<i32>)> = specs
            .iter()
            .map(|(_, _, chain)| chain_inputs(chain, t_shape))
            .collect();
        let mut lanes: Vec<LaneStep<'_>> = kvs
            .iter_mut()
            .zip(specs.iter())
            .zip(inputs.iter())
            .map(|((kv, (v, _, chain)), (tk, mk, dp))| LaneStep {
                variant: *v,
                kv,
                pos: 0,
                live: chain.len(),
                tokens: tk,
                mask: mk,
                depths: dp,
            })
            .collect();
        let out = if path == 0 {
            be.step_batch(t_shape, &mut lanes).unwrap()
        } else {
            DefaultBatch(&be).step_batch(t_shape, &mut lanes).unwrap()
        };
        drop(lanes);
        let caches: Vec<Vec<f32>> = kvs.iter().map(|kv| host(kv).to_vec()).collect();
        results.push((out, caches));
    }
    assert_eq!(results[0].0, results[1].0, "logits differ between paths");
    assert_eq!(results[0].1, results[1].1, "KV caches differ between paths");
}

/// A hermetic all-variants runtime with an explicit thread budget.
fn runtime_with_threads(threads: usize) -> ScaleRuntime {
    let mut rt = Runtime::open_with(Path::new("/missing-artifacts"), BackendSelect::Ref)
        .expect("ref runtime");
    rt.set_threads(threads);
    rt.load_scale("small", &Variant::ALL).expect("load small")
}

#[test]
fn engines_byte_identical_across_thread_counts() {
    // threads=4 vs threads=1 generations must be byte-identical for all
    // 12 engines. The 40-token prompt prefills at T>=16, exercising the
    // head-parallel attention path; every verify/draft step runs through
    // the same kernels on both runtimes.
    let srt1 = runtime_with_threads(1);
    let srt4 = runtime_with_threads(4);
    let opts = EngineOpts::default();
    let prompt: Vec<u32> = (0..40u32).map(|i| 26 + (i * 7) % 240).collect();
    for name in ENGINES {
        let mut e1 = build_engine(name, &srt1, &opts).unwrap();
        let mut e4 = build_engine(name, &srt4, &opts).unwrap();
        let g1 = e1.generate(&prompt, 10).unwrap().tokens;
        let g4 = e4.generate(&prompt, 10).unwrap().tokens;
        assert_eq!(g1, g4, "{name}: threaded generation diverged from serial");
    }
}

/// Drive concurrent runs exactly like the server's lock-step scheduler:
/// draft all, fuse every pending verify into ONE step_batch at the
/// group's widest shape, absorb in lane order — until all runs finish.
fn drive_lockstep(runs: &mut [Box<dyn RequestRun + '_>], srt: &ScaleRuntime) {
    loop {
        let mut shapes: Vec<Option<usize>> = Vec::with_capacity(runs.len());
        let mut group_t = 0usize;
        for run in runs.iter_mut() {
            if run.is_done() {
                shapes.push(None);
                continue;
            }
            match run.begin_round().unwrap() {
                RoundPhase::Done(_) => shapes.push(None),
                RoundPhase::Pending { t_shape } => {
                    group_t = group_t.max(t_shape);
                    shapes.push(Some(t_shape));
                }
            }
        }
        if group_t == 0 {
            break; // no run has a pending step: everything finished
        }
        let mut lanes: Vec<BatchLane<'_>> = Vec::new();
        for (run, sh) in runs.iter_mut().zip(&shapes) {
            if sh.is_some() {
                assert!(run.target_headroom() >= group_t, "test stays below s_max");
                lanes.push(run.take_lane(group_t).unwrap());
            }
        }
        let outs = srt.step_batch(group_t, &mut lanes).unwrap();
        drop(lanes);
        let mut outs = outs.into_iter();
        for (run, sh) in runs.iter_mut().zip(&shapes) {
            if sh.is_some() {
                run.finish_round(outs.next().unwrap(), group_t).unwrap();
            }
        }
    }
}

#[test]
fn lockstep_fused_runs_match_generate() {
    // Fused execution on a threaded runtime vs solo generate on a serial
    // runtime: the strongest combination — lane fusion, shape padding,
    // and thread parallelism together must not move a single token.
    let srt_serial = runtime_with_threads(1);
    let srt_fused = runtime_with_threads(4);
    let opts = EngineOpts::default();
    let prompts: [&[u32]; 3] = [&[1, 30, 40, 50], &[2, 35, 45, 55, 65], &[3, 36, 46]];
    for name in ["ar", "lade", "pld", "swift", "kangaroo", "vchc", "tr", "cas-spec"] {
        let mut solo_eng = build_engine(name, &srt_serial, &opts).unwrap();
        let solo: Vec<Vec<u32>> = prompts
            .iter()
            .map(|p| solo_eng.generate(p, 6).unwrap().tokens)
            .collect();

        let eng = build_engine(name, &srt_fused, &opts).unwrap();
        let mut runs: Vec<Box<dyn RequestRun + '_>> = prompts
            .iter()
            .map(|p| eng.begin(p, 6).unwrap())
            .collect();
        drive_lockstep(&mut runs, &srt_fused);
        for (i, run) in runs.iter().enumerate() {
            assert!(run.is_done(), "{name}: lane {i} still running");
            assert_eq!(
                run.tokens(),
                &solo[i][..],
                "{name}: lane {i} diverged under lock-step fusion"
            );
        }
    }
}

#[test]
fn scale_runtime_step_batch_counts_lanes() {
    // the generic-layer wrapper: per-lane StepOutputs, per-variant counters
    let rt = Runtime::open_with(Path::new("/missing-artifacts"), BackendSelect::Ref)
        .expect("ref runtime");
    let srt = rt.load_scale("small", &[Variant::Target, Variant::Ls40]).unwrap();

    let mut kv_a = srt.new_kv(Variant::Target).unwrap();
    let mut kv_b = srt.new_kv(Variant::Ls40).unwrap();
    let (tk_a, mk_a, dp_a) = chain_inputs(&[1, 30, 40], 8);
    let (tk_b, mk_b, dp_b) = chain_inputs(&[2, 31], 8);
    let mut lanes = vec![
        BatchLane { kv: &mut kv_a, live: 3, tokens: tk_a, mask: mk_a, depths: dp_a },
        BatchLane { kv: &mut kv_b, live: 2, tokens: tk_b, mask: mk_b, depths: dp_b },
    ];
    let outs = srt.step_batch(8, &mut lanes).unwrap();
    drop(lanes);
    assert_eq!(outs.len(), 2);
    assert_eq!(outs[0].logits.len(), 8 * srt.vocab());
    assert_eq!(outs[1].logits.len(), 8 * srt.vocab());
    // positions are NOT advanced by a step (commit does that)
    assert_eq!(kv_a.pos, 0);
    assert_eq!(kv_b.pos, 0);
    assert_eq!(srt.counters(Variant::Target).steps, 1);
    assert_eq!(srt.counters(Variant::Target).tokens_stepped, 3);
    assert_eq!(srt.counters(Variant::Ls40).steps, 1);
    assert_eq!(srt.counters(Variant::Ls40).tokens_stepped, 2);
}
