//! End-to-end serving test: start the TCP server on a fixed port, issue
//! concurrent requests from several client threads, verify the responses
//! equal direct engine output, then shut down cleanly.
//!
//! Hermetic: the worker falls back to the reference backend when no
//! artifacts exist, so this always runs.

use std::thread;
use std::time::Duration;

use cas_spec::config::RunConfig;
use cas_spec::engine::{build_engine, EngineOpts};
use cas_spec::model::Variant;
use cas_spec::runtime::Runtime;
use cas_spec::server::{serve, Client};
use cas_spec::workload::{Language, Suite};

#[test]
fn serve_generate_stats_shutdown() {
    let rt = Runtime::open(&Runtime::default_dir()).expect("runtime open");
    // expected outputs computed directly (losslessness makes this exact)
    let srt = rt.load_scale("small", &[Variant::Target]).unwrap();
    let lang = Language::build(rt.manifest.lang_seed);
    let suite = Suite::spec_bench(&lang, 33, 1, 12);
    let mut ar = build_engine("ar", &srt, &EngineOpts::default()).unwrap();
    let expected: Vec<Vec<u32>> = suite
        .items
        .iter()
        .take(3)
        .map(|it| ar.generate(&it.prompt, it.max_new).unwrap().tokens)
        .collect();

    let mut cfg = RunConfig::default();
    cfg.scale = "small".into();
    cfg.engines = vec!["pld".into()]; // lossless => same tokens as AR
    cfg.addr = "127.0.0.1:7531".into();
    let addr = cfg.addr.clone();
    let server = thread::spawn(move || serve(&cfg));

    // wait for the listener
    let mut client = None;
    for _ in 0..100 {
        match Client::connect(&addr) {
            Ok(c) => {
                client = Some(c);
                break;
            }
            Err(_) => thread::sleep(Duration::from_millis(100)),
        }
    }
    let mut client = client.expect("server did not come up");

    // concurrent clients
    let addr2 = addr.clone();
    let item1 = suite.items[1].clone();
    let want1 = expected[1].clone();
    let handle = thread::spawn(move || {
        let mut c = Client::connect(&addr2).unwrap();
        let resp = c.generate(42, &item1.prompt, item1.max_new).unwrap();
        let got: Vec<u32> = resp
            .req("tokens")
            .unwrap()
            .usize_arr()
            .unwrap()
            .into_iter()
            .map(|t| t as u32)
            .collect();
        assert_eq!(got, want1, "concurrent client got wrong tokens");
    });

    for (i, item) in suite.items.iter().take(3).enumerate() {
        if i == 1 {
            continue; // handled by the concurrent client
        }
        let resp = client.generate(i as u64, &item.prompt, item.max_new).unwrap();
        assert!(resp.get("error").is_none(), "server error: {resp}");
        let got: Vec<u32> = resp
            .req("tokens")
            .unwrap()
            .usize_arr()
            .unwrap()
            .into_iter()
            .map(|t| t as u32)
            .collect();
        assert_eq!(got, expected[i], "item {i}");
        assert!(resp.req("ms").unwrap().as_f64().unwrap() > 0.0);
        assert!(resp.get("text").is_some());
    }
    handle.join().unwrap();

    // stats reflect the served requests
    let stats = client.stats().unwrap();
    assert!(stats.req("served").unwrap().as_u64().unwrap() >= 3);
    assert_eq!(stats.req("engine").unwrap().as_str().unwrap(), "pld");
    let backend = stats.req("backend").unwrap().as_str().unwrap().to_string();
    assert!(backend == "ref" || backend == "pjrt", "unexpected backend {backend:?}");

    // malformed request gets an error, not a hang
    let resp = client.request_raw(r#"{"prompt": "nope"}"#).unwrap();
    assert!(resp.get("error").is_some());

    client.shutdown().unwrap();
    server.join().unwrap().unwrap();
}
