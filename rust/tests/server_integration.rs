//! End-to-end serving tests: start the TCP server on a fixed port, issue
//! concurrent requests from several client threads, verify the responses
//! equal direct engine output, then shut down cleanly.
//!
//! `continuous_batching_is_lossless_and_interleaves` is the acceptance
//! test for the continuous-batching scheduler: N concurrent clients must
//! share one running batch (stats `peak_batch` > 1) and every response
//! must equal the same request served alone (greedy losslessness under
//! batching). `lockstep_fused_serving_matches_per_lane` extends it to
//! lock-step lane fusion: fused-verify serving must produce byte-identical
//! transcripts to per-lane serving while `fused_lanes > fused_steps`
//! proves co-batched requests shared forwards.
//!
//! Hermetic: the worker falls back to the reference backend when no
//! artifacts exist, so this always runs.
//!
//! The whole suite honors `CAS_SPEC_PREFIX_CACHE_MB` (CI runs it with
//! the cross-request prefix cache off *and* on — losslessness must hold
//! either way); `prefix_cache_stats_prove_reuse` additionally forces the
//! cache on and asserts the reuse counters move. It also honors
//! `CAS_SPEC_SERVER_ENGINE` (default `pld`): CI re-runs the suite with
//! the quantized cascade `casc-aq` so int8-activation drafting is proven
//! lossless end to end through the server, at `CAS_SPEC_THREADS=1` and
//! at default threads. Every expected transcript is computed against AR
//! or the direct engine, so any lossless engine must pass unchanged.
//!
//! Two more sweep knobs ride the same pattern: `CAS_SPEC_KV_BUDGET_MB`
//! (CI runs the suite under a tiny global KV budget, forcing preemption
//! swaps on every concurrent test) and `CAS_SPEC_PREFILL_CHUNK` (CI runs
//! it with chunked prefill on). Both are lossless by construction, so no
//! assertion anywhere changes; `preemption_under_tiny_budget_is_lossless`
//! and `chunked_prefill_serving_is_lossless` additionally force each knob
//! on and assert the swap/chunk machinery actually engaged.
//!
//! The `chaos_*` tests are the failure-domain suite: seeded fault plans
//! (`--faults`, honoring `CAS_SPEC_FAULTS` for the CI chaos leg),
//! injected disconnects, deadlines, cancellation, wire bounds, and the
//! degrade ladder. Every chaos test pins the same invariants: the worker
//! never dies (the final stats/shutdown round-trip proves it), completed
//! transcripts stay byte-identical to direct AR, faulted/expired requests
//! get error or partial replies, KV leases fully release (`kv_bytes`
//! returns to 0), and the fault ledger reconciles
//! (`faults_injected == retried + retired_fault`). These tests set
//! `cfg.faults` explicitly, so the rest of the suite — whose servers
//! would otherwise inherit an ambient `CAS_SPEC_FAULTS` — must be run
//! without that variable (the CI chaos leg filters to `chaos_`).

use std::thread;
use std::time::Duration;

use cas_spec::config::RunConfig;
use cas_spec::engine::{build_engine, required_variants, EngineOpts};
use cas_spec::model::Variant;
use cas_spec::runtime::Runtime;
use cas_spec::server::{serve, Client};
use cas_spec::spec::SamplingParams;
use cas_spec::workload::{Language, Suite, WorkItem};

/// Prefix-cache budget for the suite: the CI matrix leg sets
/// `CAS_SPEC_PREFIX_CACHE_MB` to run everything with the cache off (0)
/// and on; locally it defaults to off (the seed behavior).
fn env_prefix_cache_mb() -> usize {
    std::env::var("CAS_SPEC_PREFIX_CACHE_MB")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(0)
}

/// Engine under test: the CI matrix leg sets `CAS_SPEC_SERVER_ENGINE`
/// (e.g. to the quantized cascade `casc-aq`) to push the whole suite
/// through a different lossless engine; defaults to `pld`. The server's
/// worker loads whatever variants the engine requires, so quantized
/// engines exercise the int8 forward path end to end.
fn env_engine() -> String {
    std::env::var("CAS_SPEC_SERVER_ENGINE").unwrap_or_else(|_| "pld".into())
}

/// Global KV byte budget for the suite: the CI matrix leg sets
/// `CAS_SPEC_KV_BUDGET_MB` to a value small enough that the concurrent
/// tests must preempt (swap runs out to host memory and back); locally it
/// defaults to unbounded (0). Transcripts are identical either way.
fn env_kv_budget_mb() -> usize {
    std::env::var("CAS_SPEC_KV_BUDGET_MB")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(0)
}

/// Prefill chunk size for the suite: the CI matrix leg sets
/// `CAS_SPEC_PREFILL_CHUNK` to a small value so every prompt is committed
/// in several bounded chunks; locally it defaults to monolithic (0).
/// Transcripts are identical either way.
fn env_prefill_chunk() -> usize {
    std::env::var("CAS_SPEC_PREFILL_CHUNK")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(0)
}

/// Apply the suite-wide env sweeps to a server config.
fn apply_env_sweeps(cfg: &mut RunConfig) {
    cfg.prefix_cache_mb = env_prefix_cache_mb();
    cfg.kv_budget_mb = env_kv_budget_mb();
    cfg.opts.prefill_chunk = env_prefill_chunk();
}

/// Wait until the server accepts connections AND its worker answers a
/// stats round-trip (engine built, scheduler live).
fn wait_ready(addr: &str) -> Client {
    for _ in 0..100 {
        if let Ok(mut c) = Client::connect(addr) {
            if c.stats().is_ok() {
                return c;
            }
        }
        thread::sleep(Duration::from_millis(100));
    }
    panic!("server did not come up on {addr}");
}

#[test]
fn serve_generate_stats_shutdown() {
    let rt = Runtime::open(&Runtime::default_dir()).expect("runtime open");
    // expected outputs computed directly (losslessness makes this exact)
    let srt = rt.load_scale("small", &[Variant::Target]).unwrap();
    let lang = Language::build(rt.manifest.lang_seed);
    let suite = Suite::spec_bench(&lang, 33, 1, 12);
    let mut ar = build_engine("ar", &srt, &EngineOpts::default()).unwrap();
    let expected: Vec<Vec<u32>> = suite
        .items
        .iter()
        .take(3)
        .map(|it| ar.generate(&it.prompt, it.max_new).unwrap().tokens)
        .collect();

    let mut cfg = RunConfig::default();
    cfg.scale = "small".into();
    cfg.engines = vec![env_engine()]; // lossless => same tokens as AR
    cfg.addr = "127.0.0.1:7531".into();
    apply_env_sweeps(&mut cfg);
    let addr = cfg.addr.clone();
    let server = thread::spawn(move || serve(&cfg));

    // wait for the listener
    let mut client = None;
    for _ in 0..100 {
        match Client::connect(&addr) {
            Ok(c) => {
                client = Some(c);
                break;
            }
            Err(_) => thread::sleep(Duration::from_millis(100)),
        }
    }
    let mut client = client.expect("server did not come up");

    // concurrent clients
    let addr2 = addr.clone();
    let item1 = suite.items[1].clone();
    let want1 = expected[1].clone();
    let handle = thread::spawn(move || {
        let mut c = Client::connect(&addr2).unwrap();
        let resp = c.generate(42, &item1.prompt, item1.max_new).unwrap();
        let got: Vec<u32> = resp
            .req("tokens")
            .unwrap()
            .usize_arr()
            .unwrap()
            .into_iter()
            .map(|t| t as u32)
            .collect();
        assert_eq!(got, want1, "concurrent client got wrong tokens");
    });

    for (i, item) in suite.items.iter().take(3).enumerate() {
        if i == 1 {
            continue; // handled by the concurrent client
        }
        let resp = client.generate(i as u64, &item.prompt, item.max_new).unwrap();
        assert!(resp.get("error").is_none(), "server error: {resp}");
        let got: Vec<u32> = resp
            .req("tokens")
            .unwrap()
            .usize_arr()
            .unwrap()
            .into_iter()
            .map(|t| t as u32)
            .collect();
        assert_eq!(got, expected[i], "item {i}");
        assert!(resp.req("ms").unwrap().as_f64().unwrap() > 0.0);
        assert!(resp.get("text").is_some());
    }
    handle.join().unwrap();

    // stats reflect the served requests
    let stats = client.stats().unwrap();
    assert!(stats.req("served").unwrap().as_u64().unwrap() >= 3);
    assert_eq!(stats.req("engine").unwrap().as_str().unwrap(), env_engine());
    let backend = stats.req("backend").unwrap().as_str().unwrap().to_string();
    assert!(backend == "ref" || backend == "pjrt", "unexpected backend {backend:?}");

    // malformed request gets an error, not a hang
    let resp = client.request_raw(r#"{"prompt": "nope"}"#).unwrap();
    assert!(resp.get("error").is_some());

    client.shutdown().unwrap();
    server.join().unwrap().unwrap();
}

#[test]
fn continuous_batching_is_lossless_and_interleaves() {
    let rt = Runtime::open(&Runtime::default_dir()).expect("runtime open");
    let srt = rt.load_scale("small", &[Variant::Target]).unwrap();
    let lang = Language::build(rt.manifest.lang_seed);
    // 6 requests against max_batch=3: forces queueing AND a multi-request
    // running batch; expected outputs computed solo (losslessness = exact)
    let suite = Suite::spec_bench(&lang, 91, 1, 40);
    let items: Vec<WorkItem> = suite.items.into_iter().take(6).collect();
    assert!(items.len() >= 6, "spec_bench must yield 6 categories");
    let mut ar = build_engine("ar", &srt, &EngineOpts::default()).unwrap();
    let expected: Vec<Vec<u32>> = items
        .iter()
        .map(|it| ar.generate(&it.prompt, it.max_new).unwrap().tokens)
        .collect();

    let mut cfg = RunConfig::default();
    cfg.scale = "small".into();
    cfg.engines = vec![env_engine()]; // lossless => same tokens as AR
    cfg.addr = "127.0.0.1:7532".into();
    cfg.max_batch = 3;
    apply_env_sweeps(&mut cfg);
    let addr = cfg.addr.clone();
    let server = thread::spawn(move || serve(&cfg));
    let mut control = wait_ready(&addr);

    // ---- N concurrent clients, one request each ----
    let mut handles = Vec::new();
    for (i, item) in items.iter().enumerate() {
        let addr = addr.clone();
        let item = item.clone();
        handles.push(thread::spawn(move || {
            let mut c = Client::connect(&addr).unwrap();
            let resp = c.generate(i as u64, &item.prompt, item.max_new).unwrap();
            assert!(resp.get("error").is_none(), "server error: {resp}");
            assert!(resp.req("ms").unwrap().as_f64().unwrap() > 0.0);
            assert!(resp.req("queued_ms").unwrap().as_f64().unwrap() >= 0.0);
            assert!(resp.req("batch").unwrap().as_usize().unwrap() >= 1);
            let got: Vec<u32> = resp
                .req("tokens")
                .unwrap()
                .usize_arr()
                .unwrap()
                .into_iter()
                .map(|t| t as u32)
                .collect();
            (i, got)
        }));
    }

    // sample live stats while requests are in flight (control-plane calls
    // interleave with decode rounds instead of waiting behind them)
    let mut saw_running = 0usize;
    for _ in 0..200 {
        let s = control.stats().unwrap();
        let running = s.req("running").unwrap().as_usize().unwrap();
        saw_running = saw_running.max(running);
        if running == 0 && saw_running > 0 {
            break;
        }
        thread::sleep(Duration::from_millis(2));
    }

    for h in handles {
        let (i, got) = h.join().unwrap();
        assert_eq!(
            got, expected[i],
            "request {i}: batched tokens differ from solo serving"
        );
    }

    // ---- stats must prove the batch actually interleaved ----
    let stats = control.stats().unwrap();
    assert!(stats.req("served").unwrap().as_u64().unwrap() >= 6);
    assert_eq!(stats.req("max_batch").unwrap().as_usize().unwrap(), 3);
    let peak = stats.req("peak_batch").unwrap().as_usize().unwrap();
    assert!(
        peak >= 2,
        "6 concurrent requests never shared a running batch (peak_batch={peak})"
    );
    assert_eq!(stats.req("queue_depth").unwrap().as_usize().unwrap(), 0);
    assert_eq!(stats.req("running").unwrap().as_usize().unwrap(), 0);
    assert!(stats.req("tok_s").unwrap().as_f64().unwrap() > 0.0);
    assert!(stats.req("busy_secs").unwrap().as_f64().unwrap() > 0.0);
    assert_eq!(stats.req("sampled").unwrap().as_u64().unwrap(), 0);

    control.shutdown().unwrap();
    server.join().unwrap().unwrap();
}

/// Serve `items` from concurrent clients (one per request) on a fresh
/// server; returns tokens ordered by request index plus the final stats.
fn serve_concurrent(
    items: &[WorkItem],
    port: u16,
    max_batch: usize,
    lockstep: bool,
) -> (Vec<Vec<u32>>, cas_spec::util::json::Json) {
    let mut cfg = RunConfig::default();
    cfg.scale = "small".into();
    cfg.engines = vec![env_engine()];
    cfg.addr = format!("127.0.0.1:{port}");
    cfg.max_batch = max_batch;
    cfg.lockstep = lockstep;
    apply_env_sweeps(&mut cfg);
    let addr = cfg.addr.clone();
    let server = thread::spawn(move || serve(&cfg));
    let mut control = wait_ready(&addr);

    let mut handles = Vec::new();
    for (i, item) in items.iter().enumerate() {
        let addr = addr.clone();
        let item = item.clone();
        handles.push(thread::spawn(move || {
            let mut c = Client::connect(&addr).unwrap();
            let resp = c.generate(i as u64, &item.prompt, item.max_new).unwrap();
            assert!(resp.get("error").is_none(), "server error: {resp}");
            let got: Vec<u32> = resp
                .req("tokens")
                .unwrap()
                .usize_arr()
                .unwrap()
                .into_iter()
                .map(|t| t as u32)
                .collect();
            (i, got)
        }));
    }
    let mut outputs = vec![Vec::new(); items.len()];
    for h in handles {
        let (i, got) = h.join().unwrap();
        outputs[i] = got;
    }
    let stats = control.stats().unwrap();
    control.shutdown().unwrap();
    server.join().unwrap().unwrap();
    (outputs, stats)
}

#[test]
fn lockstep_fused_serving_matches_per_lane() {
    // The lock-step acceptance test: the same 6-request concurrent
    // workload served with per-lane stepping (--lockstep off) and with
    // fused verify steps (default) must produce byte-identical
    // transcripts, while the fused server's stats prove co-batched
    // requests actually shared forwards (fused_lanes > fused_steps).
    let rt = Runtime::open(&Runtime::default_dir()).expect("runtime open");
    let lang = Language::build(rt.manifest.lang_seed);
    let suite = Suite::spec_bench(&lang, 55, 1, 40);
    let items: Vec<WorkItem> = suite.items.into_iter().take(6).collect();
    assert!(items.len() >= 6, "spec_bench must yield 6 categories");

    let (per_lane_tokens, per_lane_stats) = serve_concurrent(&items, 7535, 4, false);
    let (fused_tokens, fused_stats) = serve_concurrent(&items, 7536, 4, true);

    assert_eq!(
        fused_tokens, per_lane_tokens,
        "lock-step fusion changed the served transcripts"
    );

    assert!(!per_lane_stats.req("lockstep").unwrap().as_bool().unwrap());
    assert_eq!(per_lane_stats.req("fused_steps").unwrap().as_u64().unwrap(), 0);

    assert!(fused_stats.req("lockstep").unwrap().as_bool().unwrap());
    let steps = fused_stats.req("fused_steps").unwrap().as_u64().unwrap();
    let lanes = fused_stats.req("fused_lanes").unwrap().as_u64().unwrap();
    assert!(steps > 0, "fused server issued no fused steps");
    assert!(
        lanes > steps,
        "6 concurrent requests never shared a fused verify (lanes={lanes}, steps={steps})"
    );
    assert!(fused_stats.req("threads").unwrap().as_usize().unwrap() >= 1);
}

/// Serve `suite` sequentially on a fresh server; returns the per-request
/// token streams plus the final stats line.
fn serve_suite(
    suite: &Suite,
    port: u16,
    prefix_cache_mb: usize,
) -> (Vec<Vec<u32>>, cas_spec::util::json::Json) {
    let mut cfg = RunConfig::default();
    cfg.scale = "small".into();
    cfg.engines = vec![env_engine()];
    cfg.addr = format!("127.0.0.1:{port}");
    apply_env_sweeps(&mut cfg);
    cfg.prefix_cache_mb = prefix_cache_mb; // explicit: this test A/Bs the cache
    let addr = cfg.addr.clone();
    let server = thread::spawn(move || serve(&cfg));
    let mut client = wait_ready(&addr);

    let mut outputs = Vec::with_capacity(suite.items.len());
    for (i, item) in suite.items.iter().enumerate() {
        let resp = client.generate(i as u64, &item.prompt, item.max_new).unwrap();
        assert!(resp.get("error").is_none(), "server error: {resp}");
        outputs.push(
            resp.req("tokens")
                .unwrap()
                .usize_arr()
                .unwrap()
                .into_iter()
                .map(|t| t as u32)
                .collect(),
        );
    }
    let stats = client.stats().unwrap();
    client.shutdown().unwrap();
    server.join().unwrap().unwrap();
    (outputs, stats)
}

/// Serve `items` with sampling enabled (temperature 0.7, seed = 100 +
/// request index) from concurrent clients on a fresh server; returns
/// tokens ordered by request index plus the final stats line.
fn serve_concurrent_sampled(
    items: &[WorkItem],
    port: u16,
    max_batch: usize,
    lockstep: bool,
    prefix_cache_mb: usize,
) -> (Vec<Vec<u32>>, cas_spec::util::json::Json) {
    let mut cfg = RunConfig::default();
    cfg.scale = "small".into();
    cfg.engines = vec![env_engine()];
    cfg.addr = format!("127.0.0.1:{port}");
    cfg.max_batch = max_batch;
    cfg.lockstep = lockstep;
    apply_env_sweeps(&mut cfg);
    cfg.prefix_cache_mb = prefix_cache_mb; // explicit: this helper A/Bs the cache
    let addr = cfg.addr.clone();
    let server = thread::spawn(move || serve(&cfg));
    let mut control = wait_ready(&addr);

    let mut handles = Vec::new();
    for (i, item) in items.iter().enumerate() {
        let addr = addr.clone();
        let item = item.clone();
        handles.push(thread::spawn(move || {
            let sp = SamplingParams { temperature: 0.7, top_p: 0.9, seed: 100 + i as u64 };
            let mut c = Client::connect(&addr).unwrap();
            let resp = c.generate_sampled(i as u64, &item.prompt, item.max_new, sp).unwrap();
            assert!(resp.get("error").is_none(), "server error: {resp}");
            let got: Vec<u32> = resp
                .req("tokens")
                .unwrap()
                .usize_arr()
                .unwrap()
                .into_iter()
                .map(|t| t as u32)
                .collect();
            (i, got)
        }));
    }
    let mut outputs = vec![Vec::new(); items.len()];
    for h in handles {
        let (i, got) = h.join().unwrap();
        outputs[i] = got;
    }
    let stats = control.stats().unwrap();
    control.shutdown().unwrap();
    server.join().unwrap().unwrap();
    (outputs, stats)
}

#[test]
fn sampled_serving_is_deterministic_across_modes() {
    // Sampled requests (temperature 0.7, per-request seed) must produce
    // byte-identical transcripts whether served solo, batched, lock-step
    // fused, or with the prefix cache on — and all equal to the engine
    // run directly, which the harness separately pins to sampled AR.
    let rt = Runtime::open(&Runtime::default_dir()).expect("runtime open");
    // load whatever the engine under test needs (quantized engines pull
    // in their draft variants)
    let srt = rt.load_scale("small", &required_variants(&env_engine())).unwrap();
    let lang = Language::build(rt.manifest.lang_seed);
    let suite = Suite::spec_bench(&lang, 77, 1, 24);
    let items: Vec<WorkItem> = suite.items.into_iter().take(4).collect();

    let mut direct = build_engine(&env_engine(), &srt, &EngineOpts::default()).unwrap();
    let expected: Vec<Vec<u32>> = items
        .iter()
        .enumerate()
        .map(|(i, it)| {
            let sp = SamplingParams { temperature: 0.7, top_p: 0.9, seed: 100 + i as u64 };
            direct.generate_sampled(&it.prompt, it.max_new, Some(sp)).unwrap().tokens
        })
        .collect();

    let (solo, _) = serve_concurrent_sampled(&items, 7537, 1, true, 0);
    let (batched, _) = serve_concurrent_sampled(&items, 7538, 3, false, 0);
    let (fused, stats) = serve_concurrent_sampled(&items, 7539, 3, true, 0);
    let (cached, _) = serve_concurrent_sampled(&items, 7540, 3, true, 8);

    assert_eq!(solo, expected, "solo sampled serving differs from direct engine");
    assert_eq!(batched, expected, "batched sampled serving diverged");
    assert_eq!(fused, expected, "lock-step fused sampled serving diverged");
    assert_eq!(cached, expected, "prefix-cached sampled serving diverged");
    assert_eq!(stats.req("sampled").unwrap().as_u64().unwrap(), items.len() as u64);
}

#[test]
fn prefix_cache_stats_prove_reuse() {
    // The acceptance criterion end to end: the same shared-prefix
    // workload served cold (cache off) and warm (cache on) must produce
    // byte-identical token streams, while the warm run reports
    // prefix_hit_tokens > 0 and steps exactly that many fewer tokens
    // (decode work is deterministic, so prefill reuse is the only delta).
    let rt = Runtime::open(&Runtime::default_dir()).expect("runtime open");
    let lang = Language::build(rt.manifest.lang_seed);
    // 4 requests sharing a 64-token (4-block) prefix + 12-token suffixes
    let suite = Suite::shared_prefix(&lang, 11, 4, 64, 12, 16);

    let (cold_tokens, cold_stats) = serve_suite(&suite, 7533, 0);
    let (warm_tokens, warm_stats) = serve_suite(&suite, 7534, 4);
    assert_eq!(warm_tokens, cold_tokens, "prefix cache changed generations");

    assert_eq!(cold_stats.req("prefix_lookups").unwrap().as_u64().unwrap(), 0);
    assert_eq!(warm_stats.req("prefix_cache_mb").unwrap().as_usize().unwrap(), 4);
    let lookups = warm_stats.req("prefix_lookups").unwrap().as_u64().unwrap();
    assert!(lookups >= 4, "every prefill must consult the cache ({lookups})");
    let hit_tokens = warm_stats.req("prefix_hit_tokens").unwrap().as_u64().unwrap();
    // requests 2..4 each reuse the whole 64-token shared prefix (the
    // first pays cold and publishes it)
    assert_eq!(hit_tokens, 3 * 64, "unexpected reuse volume");

    let cold_stepped = cold_stats.req("tokens_stepped").unwrap().as_u64().unwrap();
    let warm_stepped = warm_stats.req("tokens_stepped").unwrap().as_u64().unwrap();
    assert!(warm_stepped < cold_stepped, "reuse must skip forward passes");
    assert_eq!(
        cold_stepped - warm_stepped,
        hit_tokens,
        "every reused token must correspond to one skipped stepped token"
    );
    assert_eq!(warm_stats.req("evictions").unwrap().as_u64().unwrap(), 0);
}

#[test]
fn trace_on_vs_off_transcripts_byte_identical() {
    // The tracing acceptance test: tracing is read-only on the decode
    // path, so the same engine run with the trace recorder enabled must
    // produce byte-identical token streams. DyTC is the interesting case
    // (trace timestamps sit inside its measured draft window, so the cost
    // model may pick different cascade configs) — losslessness still pins
    // the emitted tokens.
    let rt = Runtime::open(&Runtime::default_dir()).expect("runtime open");
    let lang = Language::build(rt.manifest.lang_seed);
    let suite = Suite::spec_bench(&lang, 19, 1, 24);
    let items: Vec<WorkItem> = suite.items.into_iter().take(3).collect();

    for engine in ["ar", "pld", "cas-spec"] {
        let srt_off = rt.load_scale("small", &required_variants(engine)).unwrap();
        let srt_on = rt.load_scale("small", &required_variants(engine)).unwrap();
        srt_on.obs().enable_trace(None).unwrap(); // ring buffer only
        assert!(srt_on.obs().trace_enabled() && !srt_off.obs().trace_enabled());

        let mut e_off = build_engine(engine, &srt_off, &EngineOpts::default()).unwrap();
        let mut e_on = build_engine(engine, &srt_on, &EngineOpts::default()).unwrap();
        for item in &items {
            let off = e_off.generate(&item.prompt, item.max_new).unwrap().tokens;
            let on = e_on.generate(&item.prompt, item.max_new).unwrap().tokens;
            assert_eq!(on, off, "engine {engine}: tracing changed the transcript");
        }
        if engine != "ar" {
            // speculative engines emit per-round spans
            let lines = srt_on.obs().take_trace_lines();
            assert!(
                lines.iter().any(|l| l.contains("\"ev\":\"round\"")),
                "engine {engine}: no round events traced"
            );
        }
        assert!(srt_off.obs().take_trace_lines().is_empty(), "off runtime traced");
    }
}

#[test]
fn trace_jsonl_stream_is_wellformed() {
    // --trace-file streaming: every line the server writes must parse as
    // JSON with the two universal keys (`t_us`, `ev`), timestamps must be
    // monotone (single worker thread, one epoch), and the request
    // lifecycle events must all be present.
    use cas_spec::util::json::Json;

    let path = std::env::temp_dir().join(format!("cas_spec_trace_{}.jsonl", std::process::id()));
    let _ = std::fs::remove_file(&path);

    let rt = Runtime::open(&Runtime::default_dir()).expect("runtime open");
    let lang = Language::build(rt.manifest.lang_seed);
    let suite = Suite::spec_bench(&lang, 23, 1, 16);
    let items: Vec<WorkItem> = suite.items.into_iter().take(2).collect();

    let mut cfg = RunConfig::default();
    cfg.scale = "small".into();
    cfg.engines = vec![env_engine()];
    cfg.addr = "127.0.0.1:7541".into();
    apply_env_sweeps(&mut cfg);
    cfg.trace_file = Some(path.clone());
    let addr = cfg.addr.clone();
    let server = thread::spawn(move || serve(&cfg));
    let mut client = wait_ready(&addr);
    for (i, item) in items.iter().enumerate() {
        let resp = client.generate(i as u64, &item.prompt, item.max_new).unwrap();
        assert!(resp.get("error").is_none(), "server error: {resp}");
    }
    client.shutdown().unwrap();
    server.join().unwrap().unwrap(); // serve() joins the worker: file is complete

    let text = std::fs::read_to_string(&path).expect("trace file written");
    let _ = std::fs::remove_file(&path);
    let mut evs = Vec::new();
    let mut last_t = 0u64;
    let mut n = 0usize;
    for line in text.lines() {
        let j = Json::parse(line).unwrap_or_else(|e| panic!("bad trace line {line:?}: {e}"));
        let t = j.req("t_us").unwrap().as_u64().unwrap();
        assert!(t >= last_t, "timestamps must be monotone ({t} < {last_t})");
        last_t = t;
        evs.push(j.req("ev").unwrap().as_str().unwrap().to_string());
        n += 1;
    }
    assert!(n > 0, "trace stream is empty");
    for want in ["serve", "enqueue", "admit", "prefill", "round", "retire"] {
        assert!(evs.iter().any(|e| e == want), "missing {want:?} event in {evs:?}");
    }
}

#[test]
fn metrics_cmd_exposes_histograms_and_dytc() {
    // {"cmd":"metrics"} must expose per-variant step-latency histograms
    // and the DyTC predicted-vs-realized counters, so the engine is
    // forced to cas-spec (the DyTC cascade) regardless of the suite-wide
    // CAS_SPEC_SERVER_ENGINE override.
    let rt = Runtime::open(&Runtime::default_dir()).expect("runtime open");
    let lang = Language::build(rt.manifest.lang_seed);
    let suite = Suite::spec_bench(&lang, 29, 1, 24);
    let items: Vec<WorkItem> = suite.items.into_iter().take(3).collect();

    let mut cfg = RunConfig::default();
    cfg.scale = "small".into();
    cfg.engines = vec!["cas-spec".into()];
    cfg.addr = "127.0.0.1:7542".into();
    apply_env_sweeps(&mut cfg);
    let addr = cfg.addr.clone();
    let server = thread::spawn(move || serve(&cfg));
    let mut client = wait_ready(&addr);
    for (i, item) in items.iter().enumerate() {
        let resp = client.generate(i as u64, &item.prompt, item.max_new).unwrap();
        assert!(resp.get("error").is_none(), "server error: {resp}");
    }

    let text = client.metrics().unwrap();
    assert!(text.contains("cas_spec_served_total 3"), "served counter:\n{text}");
    assert!(text.contains("cas_spec_uptime_seconds"), "uptime gauge:\n{text}");
    assert!(
        text.contains("cas_spec_step_latency_us_bucket{variant="),
        "per-variant step histograms:\n{text}"
    );
    assert!(text.contains("cas_spec_queue_wait_us_count"), "queue-wait histogram:\n{text}");
    assert!(
        text.contains("cas_spec_dytc_decisions{config="),
        "DyTC decision counters:\n{text}"
    );
    assert!(
        text.contains("cas_spec_dytc_predicted_alpha{config="),
        "DyTC predicted acceptance:\n{text}"
    );
    assert!(
        text.contains("cas_spec_dytc_realized_accept{config="),
        "DyTC realized acceptance:\n{text}"
    );

    client.shutdown().unwrap();
    server.join().unwrap().unwrap();
}

#[test]
fn responses_carry_prefill_and_decode_ms() {
    let rt = Runtime::open(&Runtime::default_dir()).expect("runtime open");
    let lang = Language::build(rt.manifest.lang_seed);
    let suite = Suite::spec_bench(&lang, 31, 1, 16);
    let item = suite.items[0].clone();

    let mut cfg = RunConfig::default();
    cfg.scale = "small".into();
    cfg.engines = vec![env_engine()];
    cfg.addr = "127.0.0.1:7543".into();
    apply_env_sweeps(&mut cfg);
    let addr = cfg.addr.clone();
    let server = thread::spawn(move || serve(&cfg));
    let mut client = wait_ready(&addr);

    let resp = client.generate(0, &item.prompt, item.max_new).unwrap();
    assert!(resp.get("error").is_none(), "server error: {resp}");
    let prefill_ms = resp.req("prefill_ms").unwrap().as_f64().unwrap();
    let decode_ms = resp.req("decode_ms").unwrap().as_f64().unwrap();
    let ms = resp.req("ms").unwrap().as_f64().unwrap();
    assert!(prefill_ms > 0.0, "prefill forward pass must take measurable time");
    assert!(decode_ms > 0.0, "decode rounds must take measurable time");
    assert!(ms > 0.0);

    let stats = client.stats().unwrap();
    let uptime = stats.req("uptime_secs").unwrap().as_f64().unwrap();
    let busy = stats.req("busy_secs").unwrap().as_f64().unwrap();
    assert!(uptime > 0.0, "worker uptime must be positive");
    assert!(busy <= uptime + 0.5, "busy time cannot exceed uptime");

    client.shutdown().unwrap();
    server.join().unwrap().unwrap();
}

#[test]
fn preemption_under_tiny_budget_is_lossless() {
    // The preemption acceptance test: a KV budget that fits only 2 of 4
    // concurrent pld sessions (2.25 MiB each at small scale, 5 MiB budget)
    // forces the scheduler to swap runs out to host memory and back, and
    // every transcript must still equal unconstrained solo serving.
    let rt = Runtime::open(&Runtime::default_dir()).expect("runtime open");
    let srt = rt.load_scale("small", &[Variant::Target]).unwrap();
    let lang = Language::build(rt.manifest.lang_seed);
    let suite = Suite::spec_bench(&lang, 47, 1, 40);
    let items: Vec<WorkItem> = suite.items.into_iter().take(4).collect();
    let mut ar = build_engine("ar", &srt, &EngineOpts::default()).unwrap();
    let expected: Vec<Vec<u32>> = items
        .iter()
        .map(|it| ar.generate(&it.prompt, it.max_new).unwrap().tokens)
        .collect();

    let mut cfg = RunConfig::default();
    cfg.scale = "small".into();
    cfg.engines = vec!["pld".into()]; // known footprint: target KV only
    cfg.addr = "127.0.0.1:7544".into();
    cfg.max_batch = 4;
    cfg.kv_budget_mb = 5;
    let addr = cfg.addr.clone();
    let server = thread::spawn(move || serve(&cfg));
    let mut control = wait_ready(&addr);

    let mut handles = Vec::new();
    for (i, item) in items.iter().enumerate() {
        let addr = addr.clone();
        let item = item.clone();
        handles.push(thread::spawn(move || {
            let mut c = Client::connect(&addr).unwrap();
            let resp = c.generate(i as u64, &item.prompt, item.max_new).unwrap();
            assert!(resp.get("error").is_none(), "server error: {resp}");
            let got: Vec<u32> = resp
                .req("tokens")
                .unwrap()
                .usize_arr()
                .unwrap()
                .into_iter()
                .map(|t| t as u32)
                .collect();
            (i, got)
        }));
    }
    for h in handles {
        let (i, got) = h.join().unwrap();
        assert_eq!(got, expected[i], "request {i}: preemption changed the transcript");
    }

    let stats = control.stats().unwrap();
    assert_eq!(stats.req("kv_budget").unwrap().as_u64().unwrap(), 5 << 20);
    assert_eq!(stats.req("served").unwrap().as_u64().unwrap(), 4);
    assert_eq!(stats.req("errors").unwrap().as_u64().unwrap(), 0);
    assert_eq!(stats.req("suspended").unwrap().as_usize().unwrap(), 0);
    let swaps_out = stats.req("swaps_out").unwrap().as_u64().unwrap();
    let swaps_in = stats.req("swaps_in").unwrap().as_u64().unwrap();
    assert!(swaps_out >= 1, "4 sessions against a 2-session budget never swapped out");
    assert_eq!(swaps_in, swaps_out, "every swapped-out run must be swapped back in");

    control.shutdown().unwrap();
    server.join().unwrap().unwrap();
}

#[test]
fn chunked_prefill_serving_is_lossless() {
    // Chunked prefill through the server: the same concurrent workload
    // served monolithic and with --prefill-chunk 4 must return
    // byte-identical transcripts, and the chunked run's trace must show
    // the prompts actually being committed in chunks.
    use cas_spec::util::json::Json;

    let rt = Runtime::open(&Runtime::default_dir()).expect("runtime open");
    let lang = Language::build(rt.manifest.lang_seed);
    let suite = Suite::spec_bench(&lang, 61, 1, 24);
    let items: Vec<WorkItem> = suite.items.into_iter().take(4).collect();

    let trace_path =
        std::env::temp_dir().join(format!("cas_spec_chunk_trace_{}.jsonl", std::process::id()));
    let _ = std::fs::remove_file(&trace_path);

    let mut outputs: Vec<Vec<Vec<u32>>> = Vec::new();
    for (port, chunk) in [(7545u16, 0usize), (7546, 4)] {
        let mut cfg = RunConfig::default();
        cfg.scale = "small".into();
        cfg.engines = vec![env_engine()];
        cfg.addr = format!("127.0.0.1:{port}");
        cfg.max_batch = 3;
        cfg.opts.prefill_chunk = chunk;
        if chunk > 0 {
            cfg.trace_file = Some(trace_path.clone());
        }
        let addr = cfg.addr.clone();
        let server = thread::spawn(move || serve(&cfg));
        let mut control = wait_ready(&addr);

        let mut handles = Vec::new();
        for (i, item) in items.iter().enumerate() {
            let addr = addr.clone();
            let item = item.clone();
            handles.push(thread::spawn(move || {
                let mut c = Client::connect(&addr).unwrap();
                let resp = c.generate(i as u64, &item.prompt, item.max_new).unwrap();
                assert!(resp.get("error").is_none(), "server error: {resp}");
                let got: Vec<u32> = resp
                    .req("tokens")
                    .unwrap()
                    .usize_arr()
                    .unwrap()
                    .into_iter()
                    .map(|t| t as u32)
                    .collect();
                (i, got)
            }));
        }
        let mut got = vec![Vec::new(); items.len()];
        for h in handles {
            let (i, toks) = h.join().unwrap();
            got[i] = toks;
        }
        outputs.push(got);
        control.shutdown().unwrap();
        server.join().unwrap().unwrap();
    }
    assert_eq!(outputs[0], outputs[1], "chunked prefill changed served transcripts");

    let text = std::fs::read_to_string(&trace_path).expect("trace file written");
    let _ = std::fs::remove_file(&trace_path);
    let chunks = text
        .lines()
        .filter(|l| {
            Json::parse(l)
                .ok()
                .and_then(|j| j.get("ev").and_then(|e| e.as_str().map(String::from)))
                .as_deref()
                == Some("prefill_chunk")
        })
        .count();
    assert!(chunks > 0, "chunked run traced no prefill_chunk events");
}

#[test]
fn queue_full_requests_are_shed() {
    // Admission-queue bound: with max_batch=1 and max_queue=1, at most two
    // requests can be in the system; firing 6 concurrently must shed some
    // with the exact {"id":N,"error":"queue full"} reply, the `shed`
    // counter must equal the observed rejections, and sheds must NOT count
    // as errors (they are load management, not failures).
    let rt = Runtime::open(&Runtime::default_dir()).expect("runtime open");
    let srt = rt.load_scale("small", &[Variant::Target]).unwrap();
    let lang = Language::build(rt.manifest.lang_seed);
    let suite = Suite::spec_bench(&lang, 83, 1, 120);
    let items: Vec<WorkItem> = suite.items.into_iter().take(6).collect();
    let mut ar = build_engine("ar", &srt, &EngineOpts::default()).unwrap();
    let expected: Vec<Vec<u32>> = items
        .iter()
        .map(|it| ar.generate(&it.prompt, it.max_new).unwrap().tokens)
        .collect();

    let mut cfg = RunConfig::default();
    cfg.scale = "small".into();
    cfg.engines = vec!["pld".into()];
    cfg.addr = "127.0.0.1:7547".into();
    cfg.max_batch = 1;
    cfg.max_queue = 1;
    let addr = cfg.addr.clone();
    let server = thread::spawn(move || serve(&cfg));
    let mut control = wait_ready(&addr);

    let mut handles = Vec::new();
    for (i, item) in items.iter().enumerate() {
        let addr = addr.clone();
        let item = item.clone();
        handles.push(thread::spawn(move || {
            let mut c = Client::connect(&addr).unwrap();
            let resp = c.generate(i as u64, &item.prompt, item.max_new).unwrap();
            if let Some(err) = resp.get("error") {
                assert_eq!(
                    err.as_str().unwrap(),
                    "queue full",
                    "unexpected error for request {i}: {resp}"
                );
                assert_eq!(
                    resp.req("id").unwrap().as_u64().unwrap(),
                    i as u64,
                    "shed reply must echo the request id"
                );
                return (i, None);
            }
            let got: Vec<u32> = resp
                .req("tokens")
                .unwrap()
                .usize_arr()
                .unwrap()
                .into_iter()
                .map(|t| t as u32)
                .collect();
            (i, Some(got))
        }));
    }
    let mut shed_seen = 0u64;
    let mut served_seen = 0u64;
    for h in handles {
        let (i, got) = h.join().unwrap();
        match got {
            Some(toks) => {
                served_seen += 1;
                assert_eq!(toks, expected[i], "request {i}: shedding changed a transcript");
            }
            None => shed_seen += 1,
        }
    }
    assert!(
        shed_seen >= 1,
        "6 concurrent requests against a 2-slot system never hit the queue bound"
    );

    let stats = control.stats().unwrap();
    assert_eq!(stats.req("shed").unwrap().as_u64().unwrap(), shed_seen);
    assert_eq!(stats.req("served").unwrap().as_u64().unwrap(), served_seen);
    assert_eq!(
        stats.req("errors").unwrap().as_u64().unwrap(),
        0,
        "sheds must not be counted as errors"
    );

    control.shutdown().unwrap();
    server.join().unwrap().unwrap();
}

#[test]
fn retired_decode_kv_is_published_to_prefix_cache() {
    // Two-turn conversation reuse: turn 2's prompt extends turn 1's
    // prompt + generated answer, so with retirement publication the
    // prefix cache must cover turn 1's *decoded* tokens, not just its
    // prompt. A 40-token prompt publishes 2 blocks (32 tokens) at
    // prefill; only the decoded suffix can push the hit past that.
    let rt = Runtime::open(&Runtime::default_dir()).expect("runtime open");
    let srt = rt.load_scale("small", &[Variant::Target]).unwrap();
    let lang = Language::build(rt.manifest.lang_seed);
    // 1 request, 32-token shared prefix + 8-token suffix = 40-token prompt
    let suite = Suite::shared_prefix(&lang, 13, 1, 32, 8, 16);
    let item = suite.items[0].clone();
    let mut ar = build_engine("ar", &srt, &EngineOpts::default()).unwrap();
    let out1 = ar.generate(&item.prompt, 16).unwrap().tokens;
    assert!(out1.len() >= 9, "turn 1 truncated too early for the block math below");
    let mut prompt2 = item.prompt.clone();
    prompt2.extend_from_slice(&out1);
    let out2 = ar.generate(&prompt2, 8).unwrap().tokens;

    let mut cfg = RunConfig::default();
    cfg.scale = "small".into();
    cfg.engines = vec!["pld".into()];
    cfg.addr = "127.0.0.1:7548".into();
    cfg.prefix_cache_mb = 8;
    let addr = cfg.addr.clone();
    let server = thread::spawn(move || serve(&cfg));
    let mut client = wait_ready(&addr);

    for (id, prompt, max_new, want) in
        [(0u64, &item.prompt, 16usize, &out1), (1, &prompt2, 8, &out2)]
    {
        let resp = client.generate(id, prompt, max_new).unwrap();
        assert!(resp.get("error").is_none(), "server error: {resp}");
        let got: Vec<u32> = resp
            .req("tokens")
            .unwrap()
            .usize_arr()
            .unwrap()
            .into_iter()
            .map(|t| t as u32)
            .collect();
        assert_eq!(&got, want, "turn {id}: served tokens differ from direct engine");
    }

    let stats = client.stats().unwrap();
    let hit_tokens = stats.req("prefix_hit_tokens").unwrap().as_u64().unwrap();
    // committed rows at turn 1's retirement: 40 (prompt) + out1 - 1, i.e.
    // >= 48 -> 3 whole blocks published; turn 2 must hit all of them
    let published = ((40 + out1.len() as u64 - 1) / 16) * 16;
    assert!(
        hit_tokens >= published.min(48),
        "turn 2 reused only {hit_tokens} tokens (prompt-only publication gives 32; \
         retirement publication must give >= 48)"
    );

    client.shutdown().unwrap();
    server.join().unwrap().unwrap();
}

// ---------------------------------------------------------------------------
// Chaos suite: failure domains, fault injection, deadlines, cancellation,
// wire bounds, and the degrade ladder (see the module header).
// ---------------------------------------------------------------------------

/// Default seeded chaos plan for the fault-storm test; the CI chaos leg
/// overrides it via `CAS_SPEC_FAULTS` (mixed step + lease + conn plans).
const DEFAULT_CHAOS_PLAN: &str = "step:0.08,lease:0.03,seed=7";

/// Fault plan for the chaos storm: `CAS_SPEC_FAULTS` when set (the CI
/// chaos leg sweeps plans the same way the other env knobs sweep), else
/// the default seeded plan.
fn env_faults() -> String {
    std::env::var("CAS_SPEC_FAULTS").unwrap_or_else(|_| DEFAULT_CHAOS_PLAN.into())
}

/// What one chaos-workload request looked like from its client.
enum ChaosOutcome {
    /// Completed normally with these tokens.
    Done(Vec<u32>),
    /// Error reply with this message.
    Errored(String),
    /// The connection died before a reply arrived (injected conn fault).
    Dropped,
}

/// Serve `items` from concurrent clients on a fresh server with an
/// explicit fault plan (empty string = force-disabled), classify each
/// client's outcome, wait for the scheduler to fully drain (dropped
/// clients' runs retire at round boundaries, after the clients return),
/// and hand back the final stats. The stats + shutdown round-trip at the
/// end is itself an assertion: a dead worker answers neither.
fn serve_with_faults(
    items: &[WorkItem],
    port: u16,
    engine: &str,
    faults: &str,
    max_batch: usize,
) -> (Vec<ChaosOutcome>, cas_spec::util::json::Json) {
    let mut cfg = RunConfig::default();
    cfg.scale = "small".into();
    cfg.engines = vec![engine.into()];
    cfg.addr = format!("127.0.0.1:{port}");
    cfg.max_batch = max_batch;
    cfg.faults = Some(faults.into());
    // chaos runs keep the cache off and the pool unbounded so the
    // end-state KV baseline is exactly zero (every lease released)
    cfg.prefix_cache_mb = 0;
    cfg.kv_budget_mb = 0;
    let addr = cfg.addr.clone();
    let server = thread::spawn(move || serve(&cfg));
    let mut control = wait_ready(&addr);

    let mut handles = Vec::new();
    for (i, item) in items.iter().enumerate() {
        let addr = addr.clone();
        let item = item.clone();
        handles.push(thread::spawn(move || {
            let mut c = Client::connect(&addr).unwrap();
            let resp = match c.generate(i as u64, &item.prompt, item.max_new) {
                Ok(r) => r,
                Err(_) => return (i, ChaosOutcome::Dropped),
            };
            assert!(
                resp.get("partial").is_none(),
                "no deadline/cancel in this workload, yet got {resp}"
            );
            if let Some(err) = resp.get("error") {
                return (i, ChaosOutcome::Errored(err.as_str().unwrap().to_string()));
            }
            let got: Vec<u32> = resp
                .req("tokens")
                .unwrap()
                .usize_arr()
                .unwrap()
                .into_iter()
                .map(|t| t as u32)
                .collect();
            (i, ChaosOutcome::Done(got))
        }));
    }
    let mut outcomes: Vec<ChaosOutcome> =
        (0..items.len()).map(|_| ChaosOutcome::Dropped).collect();
    for h in handles {
        let (i, o) = h.join().unwrap();
        outcomes[i] = o;
    }
    let stats = wait_drained(&mut control);
    control.shutdown().unwrap();
    server.join().unwrap().unwrap();
    (outcomes, stats)
}

/// Poll stats until the scheduler holds no work (dropped clients leave
/// runs behind that retire at the next round boundaries).
fn wait_drained(control: &mut Client) -> cas_spec::util::json::Json {
    for _ in 0..1000 {
        let s = control.stats().unwrap();
        if s.req("queue_depth").unwrap().as_usize().unwrap() == 0
            && s.req("running").unwrap().as_usize().unwrap() == 0
            && s.req("suspended").unwrap().as_usize().unwrap() == 0
        {
            return s;
        }
        thread::sleep(Duration::from_millis(10));
    }
    panic!("scheduler did not drain");
}

#[test]
fn chaos_step_faults_isolate_retry_and_reconcile() {
    // The chaos acceptance test, across three engines including the
    // cascade: a concurrent workload under a seeded fault plan must leave
    // the worker alive, return byte-identical-to-AR transcripts for every
    // request that completed, reply with a marked error for every request
    // a fault retired, release every KV lease, and reconcile the fault
    // ledger exactly: faults_injected == retried + retired_fault.
    let rt = Runtime::open(&Runtime::default_dir()).expect("runtime open");
    let srt = rt.load_scale("small", &[Variant::Target]).unwrap();
    let lang = Language::build(rt.manifest.lang_seed);
    let suite = Suite::spec_bench(&lang, 101, 1, 40);
    let items: Vec<WorkItem> = suite.items.into_iter().take(6).collect();
    let mut ar = build_engine("ar", &srt, &EngineOpts::default()).unwrap();
    let expected: Vec<Vec<u32>> = items
        .iter()
        .map(|it| ar.generate(&it.prompt, it.max_new).unwrap().tokens)
        .collect();

    let spec = env_faults();
    let mut total_injected = 0u64;
    for (engine, port) in [("ar", 7549u16), ("pld", 7550), ("cas-spec", 7551)] {
        let (outcomes, stats) = serve_with_faults(&items, port, engine, &spec, 3);
        let mut completed = 0u64;
        let mut errored = 0u64;
        for (i, o) in outcomes.iter().enumerate() {
            match o {
                ChaosOutcome::Done(toks) => {
                    assert_eq!(
                        toks, &expected[i],
                        "engine {engine}, request {i}: a transcript that survived \
                         the fault storm must be byte-identical to AR"
                    );
                    completed += 1;
                }
                ChaosOutcome::Errored(msg) => {
                    assert!(
                        msg.contains("injected fault"),
                        "engine {engine}, request {i}: only injected faults may \
                         fail requests in this workload, got {msg:?}"
                    );
                    errored += 1;
                }
                ChaosOutcome::Dropped => {} // injected conn fault (env plans)
            }
        }
        let injected = stats.req("faults_injected").unwrap().as_u64().unwrap();
        let retried = stats.req("retried").unwrap().as_u64().unwrap();
        let retired = stats.req("retired_fault").unwrap().as_u64().unwrap();
        assert_eq!(
            injected,
            retried + retired,
            "engine {engine}: every injected server-side fault must surface as \
             exactly one retry or one fault retirement"
        );
        total_injected += injected;
        // a retire_done whose client vanished counts served but not
        // completed, hence >= rather than ==
        assert!(stats.req("served").unwrap().as_u64().unwrap() >= completed);
        assert!(stats.req("errors").unwrap().as_u64().unwrap() >= errored);
        assert_eq!(
            stats.req("kv_bytes").unwrap().as_u64().unwrap(),
            0,
            "engine {engine}: KV leases must be fully released after the storm"
        );
    }
    if spec == DEFAULT_CHAOS_PLAN {
        // hundreds of step draws across the three engines at rate 0.08:
        // the seeded plan must actually have fired (env plans may differ)
        assert!(total_injected > 0, "the default chaos plan never injected");
    }
}

#[test]
fn chaos_faults_off_is_byte_identical_to_no_plan() {
    // Zero-overhead-when-disabled, pinned the same way tracing is: an
    // explicitly disabled plan ("") and a parsed-but-inactive plan
    // ("seed=7" — no site rates) must serve byte-identical transcripts,
    // equal to direct AR, with a zeroed fault ledger.
    let rt = Runtime::open(&Runtime::default_dir()).expect("runtime open");
    let srt = rt.load_scale("small", &[Variant::Target]).unwrap();
    let lang = Language::build(rt.manifest.lang_seed);
    let suite = Suite::spec_bench(&lang, 103, 1, 32);
    let items: Vec<WorkItem> = suite.items.into_iter().take(4).collect();
    let mut ar = build_engine("ar", &srt, &EngineOpts::default()).unwrap();
    let expected: Vec<Vec<u32>> = items
        .iter()
        .map(|it| ar.generate(&it.prompt, it.max_new).unwrap().tokens)
        .collect();

    let mut transcripts: Vec<Vec<Vec<u32>>> = Vec::new();
    for (port, faults) in [(7552u16, ""), (7553, "seed=7")] {
        let (outcomes, stats) = serve_with_faults(&items, port, "cas-spec", faults, 3);
        let toks: Vec<Vec<u32>> = outcomes
            .into_iter()
            .map(|o| match o {
                ChaosOutcome::Done(t) => t,
                _ => panic!("faults-off serving must complete every request"),
            })
            .collect();
        assert_eq!(stats.req("faults_injected").unwrap().as_u64().unwrap(), 0);
        assert_eq!(stats.req("retried").unwrap().as_u64().unwrap(), 0);
        assert_eq!(stats.req("retired_fault").unwrap().as_u64().unwrap(), 0);
        transcripts.push(toks);
    }
    assert_eq!(transcripts[0], expected, "faults-off serving diverged from AR");
    assert_eq!(
        transcripts[0], transcripts[1],
        "an inactive fault plan changed the transcripts"
    );
}

#[test]
fn chaos_injected_disconnects_are_isolated_and_counted() {
    // conn:1.0 — every generate connection vanishes right after
    // dispatching its request. The scheduler must notice at a round
    // boundary, abandon each run exactly once (disconnects == N, not
    // errors), release all KV, and keep serving the control plane.
    let rt = Runtime::open(&Runtime::default_dir()).expect("runtime open");
    let lang = Language::build(rt.manifest.lang_seed);
    let suite = Suite::spec_bench(&lang, 107, 1, 40);
    let items: Vec<WorkItem> = suite.items.into_iter().take(3).collect();

    let (outcomes, stats) = serve_with_faults(&items, 7554, "pld", "conn:1.0,seed=3", 2);
    for (i, o) in outcomes.iter().enumerate() {
        assert!(
            matches!(o, ChaosOutcome::Dropped),
            "request {i}: conn:1.0 must drop every generate connection"
        );
    }
    assert_eq!(stats.req("disconnects").unwrap().as_u64().unwrap(), 3);
    assert_eq!(stats.req("served").unwrap().as_u64().unwrap(), 0);
    assert_eq!(
        stats.req("errors").unwrap().as_u64().unwrap(),
        0,
        "a vanished client is not a request failure"
    );
    assert_eq!(stats.req("kv_bytes").unwrap().as_u64().unwrap(), 0);
}

#[test]
fn chaos_deadline_partials_are_ar_prefixes() {
    // Deadlines at three scales: an already-expired deadline (0 ms) must
    // return an empty partial from the queue front; a tight mid-flight
    // deadline must return a strict AR prefix; a generous one must not
    // fire at all. Every partial is trustworthy because losslessness is
    // per-token.
    let rt = Runtime::open(&Runtime::default_dir()).expect("runtime open");
    let srt = rt.load_scale("small", &[Variant::Target]).unwrap();
    let lang = Language::build(rt.manifest.lang_seed);
    let suite = Suite::spec_bench(&lang, 109, 1, 300);
    let item = suite.items[0].clone();
    let mut ar = build_engine("ar", &srt, &EngineOpts::default()).unwrap();
    let expected = ar.generate(&item.prompt, item.max_new).unwrap().tokens;

    let mut cfg = RunConfig::default();
    cfg.scale = "small".into();
    cfg.engines = vec!["pld".into()];
    cfg.addr = "127.0.0.1:7555".into();
    cfg.faults = Some(String::new());
    let addr = cfg.addr.clone();
    let server = thread::spawn(move || serve(&cfg));
    let mut client = wait_ready(&addr);

    // 0 ms: expired before admission — deterministic empty partial
    let resp = client.generate_with_deadline(0, &item.prompt, item.max_new, 0).unwrap();
    assert_eq!(resp.req("partial").unwrap().as_str().unwrap(), "deadline");
    assert!(resp.req("tokens").unwrap().usize_arr().unwrap().is_empty());
    assert!(resp.get("error").is_none(), "a deadline partial is not an error");

    // tight: whatever came back must be a byte-identical AR prefix
    let resp = client.generate_with_deadline(1, &item.prompt, item.max_new, 40).unwrap();
    let got: Vec<u32> = resp
        .req("tokens")
        .unwrap()
        .usize_arr()
        .unwrap()
        .into_iter()
        .map(|t| t as u32)
        .collect();
    assert_eq!(
        &got[..],
        &expected[..got.len()],
        "a deadline partial must be an exact prefix of the AR transcript"
    );
    let tight_fired = match resp.get("partial") {
        Some(p) => {
            assert_eq!(p.as_str().unwrap(), "deadline");
            assert!(got.len() < expected.len(), "partial yet complete?");
            true
        }
        None => {
            assert_eq!(got, expected, "no deadline, so the reply must be complete");
            false
        }
    };

    // generous: completes normally
    let resp =
        client.generate_with_deadline(2, &item.prompt, item.max_new, 60_000).unwrap();
    assert!(resp.get("partial").is_none(), "a 60 s deadline must not fire: {resp}");
    let got: Vec<u32> = resp
        .req("tokens")
        .unwrap()
        .usize_arr()
        .unwrap()
        .into_iter()
        .map(|t| t as u32)
        .collect();
    assert_eq!(got, expected);

    let stats = client.stats().unwrap();
    let want_deadlines = 1 + u64::from(tight_fired);
    assert_eq!(stats.req("deadlines").unwrap().as_u64().unwrap(), want_deadlines);
    client.shutdown().unwrap();
    server.join().unwrap().unwrap();
}

#[test]
fn chaos_cancel_returns_partial_prefix_and_acks() {
    // {"cmd":"cancel"} from a second connection: the control connection
    // gets an immediate ack; the generate connection gets a
    // "partial":"cancelled" reply whose tokens are an exact AR prefix.
    let rt = Runtime::open(&Runtime::default_dir()).expect("runtime open");
    let srt = rt.load_scale("small", &[Variant::Target]).unwrap();
    let lang = Language::build(rt.manifest.lang_seed);
    let suite = Suite::spec_bench(&lang, 113, 1, 400);
    let item = suite.items[0].clone();
    let mut ar = build_engine("ar", &srt, &EngineOpts::default()).unwrap();
    let expected = ar.generate(&item.prompt, item.max_new).unwrap().tokens;

    let mut cfg = RunConfig::default();
    cfg.scale = "small".into();
    cfg.engines = vec!["pld".into()];
    cfg.addr = "127.0.0.1:7556".into();
    cfg.faults = Some(String::new());
    let addr = cfg.addr.clone();
    let server = thread::spawn(move || serve(&cfg));
    let mut control = wait_ready(&addr);

    let gaddr = addr.clone();
    let gitem = item.clone();
    let gen = thread::spawn(move || {
        let mut c = Client::connect(&gaddr).unwrap();
        c.generate(7, &gitem.prompt, gitem.max_new).unwrap()
    });
    thread::sleep(Duration::from_millis(30));
    let ack = control.cancel(7).unwrap();
    assert!(ack.req("ok").unwrap().as_bool().unwrap());
    assert_eq!(ack.req("id").unwrap().as_u64().unwrap(), 7);
    // cancelling an unknown id still acks (idempotent control plane)
    assert!(control.cancel(999).unwrap().req("ok").unwrap().as_bool().unwrap());

    let resp = gen.join().unwrap();
    let got: Vec<u32> = resp
        .req("tokens")
        .unwrap()
        .usize_arr()
        .unwrap()
        .into_iter()
        .map(|t| t as u32)
        .collect();
    assert_eq!(
        &got[..],
        &expected[..got.len()],
        "a cancelled partial must be an exact prefix of the AR transcript"
    );
    let fired = match resp.get("partial") {
        Some(p) => {
            assert_eq!(p.as_str().unwrap(), "cancelled");
            true
        }
        None => {
            // the run finished before the cancel landed — legal race
            assert_eq!(got, expected);
            false
        }
    };
    let stats = control.stats().unwrap();
    assert_eq!(stats.req("cancelled").unwrap().as_u64().unwrap(), u64::from(fired));
    control.shutdown().unwrap();
    server.join().unwrap().unwrap();
}

#[test]
fn chaos_wire_bounds_reject_on_the_wire() {
    // --max-prompt / --max-new-limit end to end: out-of-bounds requests
    // are rejected in the connection thread with id-carrying errors and
    // never reach the scheduler (errors stays 0), while in-bounds
    // requests keep serving losslessly.
    let rt = Runtime::open(&Runtime::default_dir()).expect("runtime open");
    let srt = rt.load_scale("small", &[Variant::Target]).unwrap();
    let lang = Language::build(rt.manifest.lang_seed);
    let suite = Suite::spec_bench(&lang, 127, 1, 8);
    let prompt8: Vec<u32> = suite.items[0].prompt.iter().copied().take(8).collect();
    let mut ar = build_engine("ar", &srt, &EngineOpts::default()).unwrap();
    let expected = ar.generate(&prompt8, 8).unwrap().tokens;

    let mut cfg = RunConfig::default();
    cfg.scale = "small".into();
    cfg.engines = vec!["pld".into()];
    cfg.addr = "127.0.0.1:7557".into();
    cfg.faults = Some(String::new());
    cfg.max_prompt = 8;
    cfg.max_new_limit = 16;
    let addr = cfg.addr.clone();
    let server = thread::spawn(move || serve(&cfg));
    let mut client = wait_ready(&addr);

    let long: Vec<u32> = suite.items[0].prompt.iter().copied().cycle().take(9).collect();
    let resp = client.generate(1, &long, 4).unwrap();
    assert_eq!(resp.req("id").unwrap().as_u64().unwrap(), 1);
    assert!(resp.req("error").unwrap().as_str().unwrap().contains("prompt too long"));

    let resp = client.generate(2, &prompt8, 64).unwrap();
    assert_eq!(resp.req("id").unwrap().as_u64().unwrap(), 2);
    assert!(resp.req("error").unwrap().as_str().unwrap().contains("max_new"));

    let resp = client.generate(3, &prompt8, 8).unwrap();
    assert!(resp.get("error").is_none(), "in-bounds request must serve: {resp}");
    let got: Vec<u32> = resp
        .req("tokens")
        .unwrap()
        .usize_arr()
        .unwrap()
        .into_iter()
        .map(|t| t as u32)
        .collect();
    assert_eq!(got, expected);

    let stats = client.stats().unwrap();
    assert_eq!(stats.req("served").unwrap().as_u64().unwrap(), 1);
    assert_eq!(
        stats.req("errors").unwrap().as_u64().unwrap(),
        0,
        "wire rejections never reach the scheduler"
    );
    client.shutdown().unwrap();
    server.join().unwrap().unwrap();
}

#[test]
fn chaos_degrade_routes_overload_to_fallback_losslessly() {
    // Degrade-don't-die end to end: cas-spec primary with an AR fallback,
    // max_batch 1 and degrade_queue 1 — a 6-request burst must push some
    // admissions onto the fallback (degraded > 0, per-reply engine field
    // says which), and every transcript stays byte-identical to AR
    // because both engines are lossless.
    let rt = Runtime::open(&Runtime::default_dir()).expect("runtime open");
    let srt = rt.load_scale("small", &[Variant::Target]).unwrap();
    let lang = Language::build(rt.manifest.lang_seed);
    let suite = Suite::spec_bench(&lang, 131, 1, 40);
    let items: Vec<WorkItem> = suite.items.into_iter().take(6).collect();
    let mut ar = build_engine("ar", &srt, &EngineOpts::default()).unwrap();
    let expected: Vec<Vec<u32>> = items
        .iter()
        .map(|it| ar.generate(&it.prompt, it.max_new).unwrap().tokens)
        .collect();

    let mut cfg = RunConfig::default();
    cfg.scale = "small".into();
    cfg.engines = vec!["cas-spec".into()];
    cfg.fallback_engine = Some("ar".into());
    cfg.degrade_queue = 1;
    cfg.max_batch = 1;
    cfg.addr = "127.0.0.1:7558".into();
    cfg.faults = Some(String::new());
    let addr = cfg.addr.clone();
    let server = thread::spawn(move || serve(&cfg));
    let mut control = wait_ready(&addr);

    let mut handles = Vec::new();
    for (i, item) in items.iter().enumerate() {
        let addr = addr.clone();
        let item = item.clone();
        handles.push(thread::spawn(move || {
            let mut c = Client::connect(&addr).unwrap();
            let resp = c.generate(i as u64, &item.prompt, item.max_new).unwrap();
            assert!(resp.get("error").is_none(), "server error: {resp}");
            let engine = resp.req("engine").unwrap().as_str().unwrap().to_string();
            let got: Vec<u32> = resp
                .req("tokens")
                .unwrap()
                .usize_arr()
                .unwrap()
                .into_iter()
                .map(|t| t as u32)
                .collect();
            (i, engine, got)
        }));
    }
    let mut fallback_served = 0u64;
    for h in handles {
        let (i, engine, got) = h.join().unwrap();
        assert!(
            engine == "cas-spec" || engine == "ar",
            "request {i}: unexpected serving engine {engine:?}"
        );
        if engine == "ar" {
            fallback_served += 1;
        }
        assert_eq!(got, expected[i], "request {i}: degradation changed the transcript");
    }
    let stats = control.stats().unwrap();
    let degraded = stats.req("degraded").unwrap().as_u64().unwrap();
    assert_eq!(degraded, fallback_served, "degraded counter vs per-reply engine fields");
    assert!(
        degraded >= 1,
        "a 6-request burst against max_batch=1, degrade_queue=1 never degraded"
    );
    assert_eq!(stats.req("errors").unwrap().as_u64().unwrap(), 0);
    control.shutdown().unwrap();
    server.join().unwrap().unwrap();
}
