//! Cross-request prefix cache: losslessness and reuse accounting.
//!
//! The load-bearing claim (docs/ARCHITECTURE.md §Prefix cache): because a
//! committed token's KV rows are a pure function of its token prefix (the
//! backend determinism contract), seeding a prefill from another
//! request's cached blocks is **bit-exact** — every engine must generate
//! byte-identical tokens with the cache enabled, while the runtime steps
//! measurably fewer tokens. Hermetic: runs on the reference backend with
//! seeded weights.

use std::path::Path;

use cas_spec::cache::BLOCK_TOKENS;
use cas_spec::engine::{build_engine, EngineOpts, ENGINES};
use cas_spec::model::Variant;
use cas_spec::runtime::{BackendSelect, Runtime, ScaleRuntime};
use cas_spec::spec::VariantSession;

/// A hermetic all-variants runtime; `cache_mb` > 0 attaches the cache.
fn runtime(cache_mb: usize) -> ScaleRuntime {
    let rt = Runtime::open_with(Path::new("/missing-artifacts"), BackendSelect::Ref)
        .expect("ref runtime");
    let mut srt = rt.load_scale("small", &Variant::ALL).expect("load small");
    srt.enable_prefix_cache(cache_mb << 20);
    srt
}

/// Prompts sharing a multi-block prefix, with distinct tails.
fn shared_prompts() -> (Vec<u32>, Vec<u32>) {
    // 3 cache blocks of region-A tokens, then per-request tails
    let prefix: Vec<u32> = (0..3 * BLOCK_TOKENS as u32).map(|i| 26 + (i * 7) % 240).collect();
    let mut p1 = prefix.clone();
    p1.extend([30, 40, 3]);
    let mut p2 = prefix;
    p2.extend([50, 60, 70, 3]);
    (p1, p2)
}

#[test]
fn cache_seeded_prefill_is_bit_identical_for_every_engine() {
    let cold = runtime(0);
    let warm = runtime(8);
    let (p1, p2) = shared_prompts();

    for name in ENGINES {
        let opts = EngineOpts::default();
        let mut ce = build_engine(name, &cold, &opts).unwrap();
        let cold1 = ce.generate(&p1, 10).unwrap().tokens;
        let cold2 = ce.generate(&p2, 10).unwrap().tokens;

        let mut we = build_engine(name, &warm, &opts).unwrap();
        // first request publishes the prefix, second reuses it
        let warm1 = we.generate(&p1, 10).unwrap().tokens;
        let warm2 = we.generate(&p2, 10).unwrap().tokens;
        assert_eq!(warm1, cold1, "{name}: publishing request diverged");
        assert_eq!(warm2, cold2, "{name}: cache-seeded request diverged");
    }

    let stats = warm.prefix_cache().unwrap().stats();
    assert!(stats.lookups > 0, "prefill never consulted the cache");
    assert!(
        stats.hit_tokens > 0,
        "shared prefixes never hit ({} lookups)",
        stats.lookups
    );
}

#[test]
fn cache_hits_skip_prefill_steps() {
    let warm = runtime(8);
    let (p1, p2) = shared_prompts();

    let mut s1 = VariantSession::new(&warm, Variant::Target).unwrap();
    s1.feed(&p1).unwrap();
    let stepped_cold = warm.counters(Variant::Target).tokens_stepped;
    assert_eq!(warm.counters(Variant::Target).tokens_reused, 0);

    let mut s2 = VariantSession::new(&warm, Variant::Target).unwrap();
    s2.feed(&p2).unwrap();
    let c = warm.counters(Variant::Target);
    assert_eq!(
        c.tokens_reused as usize,
        3 * BLOCK_TOKENS,
        "second prefill must reuse the whole shared prefix"
    );
    let stepped_warm = c.tokens_stepped - stepped_cold;
    assert_eq!(
        stepped_warm as usize,
        p2.len() - 3 * BLOCK_TOKENS,
        "second prefill must step only the suffix"
    );

    // both sessions are positioned after their full prompts
    assert_eq!(s1.pos(), p1.len());
    assert_eq!(s2.pos(), p2.len());

    // and the reused prefill continues bit-identically: same next-token
    // logits as a cold session fed the same prompt
    let cold = runtime(0);
    let mut s3 = VariantSession::new(&cold, Variant::Target).unwrap();
    s3.feed(&p2).unwrap();
    assert_eq!(s2.last_logits().unwrap(), s3.last_logits().unwrap());
}

#[test]
fn identical_prompts_reuse_everything_but_the_tail() {
    let warm = runtime(8);
    let (p1, _) = shared_prompts();

    let mut a = VariantSession::new(&warm, Variant::Target).unwrap();
    a.feed(&p1).unwrap();
    let mut b = VariantSession::new(&warm, Variant::Target).unwrap();
    b.feed(&p1).unwrap();

    // the repeat reuses every whole block of the lookup slice (the last
    // token is always stepped so post-prefill logits exist)
    let c = warm.counters(Variant::Target);
    let reusable = ((p1.len() - 1) / BLOCK_TOKENS) * BLOCK_TOKENS;
    assert_eq!(c.tokens_reused as usize, reusable);
    assert_eq!(a.last_logits().unwrap(), b.last_logits().unwrap());
}

#[test]
fn draft_variants_have_their_own_namespace() {
    let warm = runtime(8);
    let (p1, _) = shared_prompts();

    let mut t = VariantSession::new(&warm, Variant::Target).unwrap();
    t.feed(&p1).unwrap();
    // a draft session of a different variant must not hit target blocks
    let mut d = VariantSession::new(&warm, Variant::Ls40).unwrap();
    d.feed(&p1).unwrap();
    assert_eq!(
        warm.counters(Variant::Ls40).tokens_reused,
        0,
        "ls40 prefill must miss on target-published blocks"
    );
    // but a second ls40 session reuses ls40's own published blocks
    let mut d2 = VariantSession::new(&warm, Variant::Ls40).unwrap();
    d2.feed(&p1).unwrap();
    assert!(warm.counters(Variant::Ls40).tokens_reused > 0);
    assert_eq!(d.last_logits().unwrap(), d2.last_logits().unwrap());
}

#[test]
fn quantized_variant_namespace_is_isolated_and_bit_exact() {
    // aq8 runs the SAME layer set as the target but a different numeric
    // path, so its KV rows differ bitwise from the target's for the same
    // prompt. Cache namespaces must keep them apart: an aq8 prefill never
    // reuses target blocks (or vice versa), and aq8's own reuse is
    // bit-exact against a cold aq8 session.
    let warm = runtime(8);
    let (p1, _) = shared_prompts();

    let mut t = VariantSession::new(&warm, Variant::Target).unwrap();
    t.feed(&p1).unwrap();
    let mut q = VariantSession::new(&warm, Variant::Aq8).unwrap();
    q.feed(&p1).unwrap();
    assert_eq!(
        warm.counters(Variant::Aq8).tokens_reused,
        0,
        "aq8 prefill must miss on target-published blocks"
    );
    // the two variants' post-prefill logits genuinely differ — if they
    // were equal, namespace isolation would be vacuous here
    assert_ne!(
        t.last_logits().unwrap(),
        q.last_logits().unwrap(),
        "aq8 and target produced identical logits; quantization inactive?"
    );

    // a second aq8 session reuses aq8's own blocks, bit-exactly vs cold
    let mut q2 = VariantSession::new(&warm, Variant::Aq8).unwrap();
    q2.feed(&p1).unwrap();
    assert!(warm.counters(Variant::Aq8).tokens_reused > 0);
    assert_eq!(q.last_logits().unwrap(), q2.last_logits().unwrap());
    let cold = runtime(0);
    let mut qc = VariantSession::new(&cold, Variant::Aq8).unwrap();
    qc.feed(&p1).unwrap();
    assert_eq!(
        q2.last_logits().unwrap(),
        qc.last_logits().unwrap(),
        "cache-seeded aq8 prefill diverged from cold aq8"
    );

    // and the reverse direction: target still misses on aq8 blocks
    let target_reused_before = warm.counters(Variant::Target).tokens_reused;
    let mut t2 = VariantSession::new(&warm, Variant::Target).unwrap();
    t2.feed(&p1).unwrap();
    let c = warm.counters(Variant::Target);
    assert!(
        c.tokens_reused > target_reused_before,
        "target should reuse its OWN earlier blocks"
    );
    assert_eq!(t.last_logits().unwrap(), t2.last_logits().unwrap());
}

#[test]
fn export_import_roundtrip_continues_bitwise() {
    // The ScaleRuntime-level primitive under the cache: committed rows
    // exported from one request's KV seed a fresh cache that continues
    // decoding bit-identically to the donor.
    use cas_spec::spec::DraftTree;

    let srt = runtime(0);
    let n = 2 * BLOCK_TOKENS;
    let toks: Vec<u32> = (0..n as u32).map(|i| 26 + (i * 5) % 240).collect();

    let mut kv_a = srt.new_kv(Variant::Target).unwrap();
    let tree = DraftTree::chain(toks[0], &toks[1..], 64);
    let (t64, m64, d64) = tree.serialize(64, 0);
    srt.step(&mut kv_a, 64, n, &t64, &m64, &d64).unwrap();
    let slots: Vec<usize> = (0..n).collect();
    srt.commit(&mut kv_a, 64, &slots).unwrap();
    assert_eq!(kv_a.pos, n);

    let rows = srt.export_rows(&kv_a, 0, n).unwrap();
    let mut kv_b = srt.new_kv(Variant::Target).unwrap();
    srt.import_rows(&mut kv_b, n, &rows).unwrap();
    assert_eq!(kv_b.pos, n, "import advances the committed length");
    assert_eq!(srt.counters(Variant::Target).tokens_reused as usize, n);

    // continue both caches with the same next token: bitwise equal
    let la = srt.step(&mut kv_a, 1, 1, &[77], &[1.0], &[0]).unwrap();
    let lb = srt.step(&mut kv_b, 1, 1, &[77], &[1.0], &[0]).unwrap();
    assert_eq!(la.logits, lb.logits, "imported rows diverged from donor");
}
