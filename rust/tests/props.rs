//! Property-based tests over the coordinator's pure invariants (the offline
//! registry has no proptest, so cases are driven by a seeded SplitMix64 —
//! failures print the seed for replay).
//!
//! No artifacts required: everything here is model-free.

use cas_spec::analytic::{simulate, t_hc, t_sd, t_vc, Scheme};
use cas_spec::dytc::{expected_accepted, find_best_config, step_objective, AcceptanceEstimator};
use cas_spec::obs::{bucket_of, Histogram};
use cas_spec::pld::PldMatcher;
use cas_spec::runtime::reference::{dot_q8_chunked, quantize_row, Q8_CHUNK};
use cas_spec::spec::{verify_greedy, DraftTree};
use cas_spec::util::rng::SplitMix64;

const CASES: usize = 200;

fn rngs() -> impl Iterator<Item = (u64, SplitMix64)> {
    (0..CASES as u64).map(|seed| (seed, SplitMix64::new(seed.wrapping_mul(0x9E37))))
}

/// Random forest tree with `n` nodes.
fn random_tree(rng: &mut SplitMix64, n: usize, vocab: u32) -> DraftTree {
    let mut t = DraftTree::new(rng.next_below(vocab as u64) as u32, n.max(1));
    for _ in 1..n {
        let parent = rng.next_below(t.len() as u64) as usize;
        let tok = rng.next_below(vocab as u64) as u32;
        t.add_child(parent, tok, rng.next_f64(), 0, rng.next_f64());
    }
    t
}

#[test]
fn prop_verify_accepts_a_root_path() {
    for (seed, mut rng) in rngs() {
        let vocab = 32usize;
        let n = 1 + rng.next_below(16) as usize;
        let tree = random_tree(&mut rng, n, vocab as u32);
        let logits: Vec<f32> =
            (0..n * vocab).map(|_| (rng.next_f64() as f32) * 10.0).collect();
        let v = verify_greedy(&tree, &logits, vocab);
        // accepted slots form a root-anchored parent chain
        assert_eq!(v.accepted_slots[0], 0, "seed {seed}");
        for w in v.accepted_slots.windows(2) {
            assert_eq!(tree.nodes[w[1]].parent, Some(w[0]), "seed {seed}");
        }
        // every accepted child matches the parent's argmax
        for w in v.accepted_slots.windows(2) {
            let row = &logits[w[0] * vocab..(w[0] + 1) * vocab];
            assert_eq!(
                tree.nodes[w[1]].token,
                cas_spec::runtime::argmax(row),
                "seed {seed}"
            );
        }
        // bonus is the argmax at the deepest accepted slot
        let last = *v.accepted_slots.last().unwrap();
        let row = &logits[last * vocab..(last + 1) * vocab];
        assert_eq!(v.bonus, cas_spec::runtime::argmax(row), "seed {seed}");
    }
}

#[test]
fn prop_tree_masks_are_ancestor_closures() {
    for (seed, mut rng) in rngs() {
        let n = 1 + rng.next_below(16) as usize;
        let t_shape = 16;
        let tree = random_tree(&mut rng, n, 100);
        let (_toks, mask, depths) = tree.serialize(t_shape, 0);
        for i in 0..n {
            // diagonal
            assert_eq!(mask[i * t_shape + i], 1.0, "seed {seed}");
            // mask row i == exactly the ancestor set
            let path = tree.path_slots(i);
            for j in 0..n {
                let expected = path.contains(&j);
                assert_eq!(mask[i * t_shape + j] > 0.5, expected, "seed {seed} i={i} j={j}");
            }
            // depth consistency
            assert_eq!(depths[i] as usize, path.len() - 1, "seed {seed}");
        }
        // padding rows: self only
        for i in n..t_shape {
            let row = &mask[i * t_shape..(i + 1) * t_shape];
            assert_eq!(row.iter().sum::<f32>(), 1.0, "seed {seed}");
            assert_eq!(row[i], 1.0, "seed {seed}");
        }
    }
}

#[test]
fn prop_pld_proposals_are_genuine_continuations() {
    for (seed, mut rng) in rngs() {
        let len = 10 + rng.next_below(120) as usize;
        let vocab = 1 + rng.next_below(12) as u32; // small vocab => many repeats
        let corpus: Vec<u32> = (0..len).map(|_| rng.next_below(vocab as u64) as u32).collect();
        let m = PldMatcher::new(&corpus);
        if let Some(d) = m.propose(8) {
            // the proposal must literally appear right after an occurrence
            // of a matching suffix n-gram somewhere strictly earlier
            let ng = d.match_len;
            let suffix = &corpus[len - ng..];
            let mut found = false;
            for start in 0..len - ng {
                if &corpus[start..start + ng] == suffix {
                    let cont = &corpus[start + ng..];
                    if cont.len() >= d.tokens.len() && &cont[..d.tokens.len()] == d.tokens {
                        found = true;
                        break;
                    }
                }
            }
            assert!(found, "seed {seed}: proposal not grounded in corpus");
        }
    }
}

#[test]
fn prop_pld_truncate_restores_exact_state() {
    for (seed, mut rng) in rngs() {
        let base: Vec<u32> = (0..30).map(|_| rng.next_below(8) as u32).collect();
        let extra: Vec<u32> = (0..10).map(|_| rng.next_below(8) as u32).collect();
        let mut a = PldMatcher::new(&base);
        let b = a.clone();
        a.extend(&extra);
        a.truncate(base.len());
        // behaviourally identical: same proposals at several k
        for k in [1, 3, 8] {
            let pa = a.propose(k).map(|d| (d.tokens, d.match_len));
            let pb = b.propose(k).map(|d| (d.tokens, d.match_len));
            assert_eq!(pa, pb, "seed {seed} k={k}");
        }
    }
}

#[test]
fn prop_estimator_bounded_and_tracks() {
    for (seed, mut rng) in rngs().take(50) {
        let p = rng.next_f64();
        let mut est = AcceptanceEstimator::with_defaults(rng.next_f64());
        for _ in 0..300 {
            est.observe(rng.next_f64() < p);
            est.roll();
            let a = est.alpha();
            assert!((0.01..=0.99).contains(&a), "seed {seed}");
        }
        assert!(
            (est.alpha() - p).abs() < 0.25,
            "seed {seed}: alpha {} far from p {p}",
            est.alpha()
        );
    }
}

#[test]
fn prop_closed_forms_match_simulation() {
    for (seed, mut rng) in rngs().take(12) {
        let a = 0.1 + 0.8 * rng.next_f64();
        let c = 0.01 + 0.5 * rng.next_f64();
        let k = 1 + rng.next_below(10) as usize;
        let sim = simulate(Scheme::Sd { alpha: a, c, k }, 40_000, seed).speedup;
        let th = t_sd(a, c, k);
        assert!((sim - th).abs() / th < 0.03, "seed {seed}: sd {sim} vs {th}");

        let a2 = 0.1 + 0.8 * rng.next_f64();
        let c2 = 0.005 + 0.1 * rng.next_f64();
        let k2 = 1 + rng.next_below(8) as usize;
        let sim = simulate(
            Scheme::Hc { a1: a, c1: c, k1: k, a2, c2, k2 },
            40_000,
            seed ^ 1,
        )
        .speedup;
        let th = t_hc(a, a2, c, c2, k, k2);
        assert!((sim - th).abs() / th < 0.035, "seed {seed}: hc {sim} vs {th}");

        let n = 1 + rng.next_below(4) as usize;
        let sim = simulate(
            Scheme::Vc { a_t: a, a_in: a2, c1: c, c2, n, k: k2 },
            40_000,
            seed ^ 2,
        )
        .speedup;
        let th = t_vc(a, a2, c, c2, n, k2);
        assert!((sim - th).abs() / th < 0.04, "seed {seed}: vc {sim} vs {th}");
    }
}

#[test]
fn prop_find_best_config_is_argmax() {
    for (seed, mut rng) in rngs().take(100) {
        let n = 1 + rng.next_below(6) as usize;
        let alphas: Vec<f64> = (0..n).map(|_| rng.next_f64()).collect();
        let costs: Vec<f64> = (0..n).map(|_| 0.01 + rng.next_f64()).collect();
        let (a_dn, c_dn) = (rng.next_f64(), 0.005 + 0.1 * rng.next_f64());
        let k_max = 1 + rng.next_below(8) as usize;
        if let Some((ci, k)) = find_best_config(&alphas, &costs, a_dn, c_dn, k_max) {
            let best = step_objective(alphas[ci], costs[ci], k, a_dn, c_dn);
            for i in 0..n {
                for kk in 1..=k_max {
                    assert!(
                        best >= step_objective(alphas[i], costs[i], kk, a_dn, c_dn) - 1e-12,
                        "seed {seed}: ({ci},{k}) beaten by ({i},{kk})"
                    );
                }
            }
        }
    }
}

#[test]
fn prop_quantize_dequantize_error_bounded() {
    // per-row symmetric int8: |x - q·scale| ≤ scale/2 for every element
    // (round-to-nearest; the max-|x| element maps exactly to ±127)
    for (seed, mut rng) in rngs() {
        let n = 1 + rng.next_below(300) as usize;
        let row: Vec<f32> =
            (0..n).map(|_| (rng.next_f64() as f32 - 0.5) * 8.0).collect();
        let mut q = vec![0i8; n];
        let scale = quantize_row(&row, &mut q);
        let maxa = row.iter().fold(0f32, |m, v| m.max(v.abs()));
        if maxa == 0.0 {
            assert_eq!(scale, 0.0, "seed {seed}");
            assert!(q.iter().all(|&c| c == 0), "seed {seed}");
            continue;
        }
        assert!(scale > 0.0, "seed {seed}");
        // scale/2 plus a few ulps of slack for the f32 round-trip
        let bound = scale * 0.5 + maxa * 1e-6;
        for (i, (&x, &c)) in row.iter().zip(&q).enumerate() {
            let err = (x - c as f32 * scale).abs();
            assert!(err <= bound, "seed {seed} i={i}: err {err} > {bound}");
            assert!((-127..=127).contains(&(c as i32)), "seed {seed} i={i}");
        }
    }
}

#[test]
fn prop_quantize_degenerate_rows() {
    // all-zero rows must not divide by zero, and single-element rows
    // round-trip their one value exactly (it IS the max-|x| element)
    let mut q1 = [0i8; 1];
    assert_eq!(quantize_row(&[0.0], &mut q1), 0.0);
    assert_eq!(q1[0], 0);
    let mut qz = [0i8; 64];
    assert_eq!(quantize_row(&[0.0; 64], &mut qz), 0.0);
    assert!(qz.iter().all(|&c| c == 0));
    for (seed, mut rng) in rngs().take(64) {
        let x = (rng.next_f64() as f32 - 0.5) * 20.0;
        let scale = quantize_row(&[x], &mut q1);
        if x == 0.0 {
            assert_eq!(scale, 0.0, "seed {seed}");
        } else {
            assert_eq!(q1[0], if x > 0.0 { 127 } else { -127 }, "seed {seed}");
            let err = (x - q1[0] as f32 * scale).abs();
            assert!(err <= x.abs() * 1e-5, "seed {seed}: {x} vs {}", q1[0] as f32 * scale);
        }
    }
}

#[test]
fn prop_fixed_split_accumulation_is_split_invariant() {
    // integer accumulation is associative, so the chunked i32→i64 dot is
    // byte-identical for ANY chunk size — and for any contiguous split of
    // the input (the serial-vs-4-thread partition included)
    for (seed, mut rng) in rngs() {
        let n = 1 + rng.next_below(700) as usize;
        let x: Vec<i8> = (0..n).map(|_| (rng.next_below(255) as i64 - 127) as i8).collect();
        let w: Vec<i8> = (0..n).map(|_| (rng.next_below(255) as i64 - 127) as i8).collect();
        let want = dot_q8_chunked(&x, &w, Q8_CHUNK);
        // across chunk counts (incl. degenerate and oversized chunks)
        for chunk in [1usize, 3, 17, Q8_CHUNK, n, n + 13] {
            assert_eq!(dot_q8_chunked(&x, &w, chunk), want, "seed {seed} chunk={chunk}");
        }
        // across a 4-way contiguous partition (the thread-split shape):
        // partial sums over sub-ranges recombine to the same bits
        let step = n.div_ceil(4);
        let mut split_sum = 0i64;
        for part in 0..4 {
            let lo = (part * step).min(n);
            let hi = ((part + 1) * step).min(n);
            split_sum += dot_q8_chunked(&x[lo..hi], &w[lo..hi], Q8_CHUNK);
        }
        assert_eq!(split_sum, want, "seed {seed}: 4-way split diverged");
    }
}

#[test]
fn prop_expected_accepted_monotone() {
    for (seed, mut rng) in rngs().take(100) {
        let a = rng.next_f64().clamp(0.01, 0.99);
        for k in 1..10 {
            let lo = expected_accepted(a, k);
            let hi = expected_accepted(a, k + 1);
            assert!(hi >= lo - 1e-12, "seed {seed}: not monotone in k");
            assert!(lo <= k as f64 + 1e-12, "seed {seed}: exceeds k");
        }
        let b = (a + 0.3).min(0.999);
        assert!(
            expected_accepted(b, 5) >= expected_accepted(a, 5),
            "seed {seed}: not monotone in alpha"
        );
    }
}

#[test]
fn prop_histogram_quantiles_within_one_bucket_of_exact() {
    for (seed, mut rng) in rngs() {
        let n = 1 + rng.next_below(256) as usize;
        // mix magnitudes so samples span many buckets
        let samples: Vec<u64> = (0..n)
            .map(|_| {
                let shift = rng.next_below(48) as u32;
                rng.next_u64() >> shift
            })
            .collect();
        let mut h = Histogram::default();
        for &s in &samples {
            h.observe(s);
        }
        assert_eq!(h.count(), n as u64, "seed {seed}");
        let mut sorted = samples.clone();
        sorted.sort_unstable();
        for q in [0.5, 0.9, 0.99] {
            // exact nearest-rank value, same rank rule the histogram uses
            let rank = ((n as f64 * q).ceil() as usize).clamp(1, n);
            let exact = sorted[rank - 1];
            let approx = h.quantile(q);
            assert_eq!(
                bucket_of(approx),
                bucket_of(exact),
                "seed {seed} q={q}: histogram quantile {approx} not in exact \
                 value {exact}'s bucket"
            );
            assert!(approx <= exact, "seed {seed} q={q}: lower bound exceeded");
        }
    }
}
