//! Cross-backend equivalence.
//!
//! Hermetic part: the reference backend must be perfectly reproducible —
//! same seed ⇒ identical weights ⇒ identical logits and generations, even
//! across fully independent runtime instances (this is what makes the
//! lossless suite meaningful without artifacts).
//!
//! PJRT part (gated): when artifacts are present *and* the crate is built
//! with real `pjrt` bindings, per-step greedy argmax must agree between
//! the reference backend (reading the same on-disk weights) and the AOT
//! graphs. It skips only on the PJRT-specific preconditions.

use cas_spec::engine::{build_engine, EngineOpts};
use cas_spec::model::Variant;
use cas_spec::runtime::{argmax, BackendSelect, Runtime, ScaleRuntime};
use cas_spec::spec::VariantSession;
use cas_spec::workload::{Language, Suite};

const PROMPT: [u32; 7] = [1, 26, 40, 266, 30, 50, 101];

fn ref_scale(scale: &str) -> ScaleRuntime {
    let rt = Runtime::open_with(&Runtime::default_dir(), BackendSelect::Ref)
        .expect("ref runtime");
    rt.load_scale(scale, &Variant::ALL).expect("load scale")
}

#[test]
fn ref_generations_identical_across_instances() {
    let generate = |engine: &str| -> Vec<Vec<u32>> {
        let rt = Runtime::open_with(&Runtime::default_dir(), BackendSelect::Ref).unwrap();
        let srt = rt.load_scale("small", &Variant::ALL).unwrap();
        let lang = Language::build(rt.manifest.lang_seed);
        let suite = Suite::spec_bench(&lang, 3, 1, 12);
        let mut eng = build_engine(engine, &srt, &EngineOpts::default()).unwrap();
        suite
            .items
            .iter()
            .map(|it| eng.generate(&it.prompt, it.max_new).unwrap().tokens)
            .collect()
    };
    // self-consistency per engine
    assert_eq!(generate("ar"), generate("ar"));
    assert_eq!(generate("cas-spec"), generate("cas-spec"));
    // and lossless across engines, directly
    assert_eq!(generate("ar"), generate("pld"));
}

#[test]
fn ref_sessions_bitwise_identical_across_runtimes() {
    let a = ref_scale("small");
    let b = ref_scale("small");
    for v in Variant::ALL {
        let mut sa = VariantSession::new(&a, v).unwrap();
        let mut sb = VariantSession::new(&b, v).unwrap();
        sa.feed(&PROMPT).unwrap();
        sb.feed(&PROMPT).unwrap();
        assert_eq!(sa.last_logits(), sb.last_logits(), "{v:?} prefill logits");
        let mut tok = argmax(sa.last_logits().unwrap());
        for step in 0..5 {
            let la = sa.decode_one(tok).unwrap().to_vec();
            let lb = sb.decode_one(tok).unwrap();
            assert_eq!(la, lb, "{v:?} step {step}: logits diverged");
            tok = argmax(&la);
        }
    }
}

#[test]
fn ref_larger_scales_load_and_decode() {
    // base exercises non-small dims (8 layers, d=192, 6 heads)
    let srt = ref_scale("base");
    let mut s = VariantSession::new(&srt, Variant::Target).unwrap();
    s.feed(&PROMPT).unwrap();
    let l = s.last_logits().unwrap();
    assert_eq!(l.len(), srt.vocab());
    assert!(l.iter().all(|x| x.is_finite()));
    let t = argmax(l);
    assert!((t as usize) < srt.vocab());
}

/// RefBackend argmax == PJRT argmax per step when artifacts are present.
/// Skips only on the PJRT-specific preconditions (no artifacts, stub xla,
/// or a build without the `pjrt` feature).
#[test]
fn ref_matches_pjrt_argmax_per_step() {
    #[cfg(feature = "pjrt")]
    {
        let dir = Runtime::default_dir();
        let Ok(pjrt_rt) = Runtime::open_with(&dir, BackendSelect::Pjrt) else {
            eprintln!("skipping: PJRT unavailable (no artifacts or stub xla bindings)");
            return;
        };
        let ref_rt = Runtime::open_with(&dir, BackendSelect::Ref).unwrap();
        let p = pjrt_rt.load_scale("small", &Variant::ALL).unwrap();
        let r = ref_rt.load_scale("small", &Variant::ALL).unwrap();
        for v in Variant::ALL {
            let mut sp = VariantSession::new(&p, v).unwrap();
            let mut sr = VariantSession::new(&r, v).unwrap();
            sp.feed(&PROMPT).unwrap();
            sr.feed(&PROMPT).unwrap();
            let mut tok = argmax(sp.last_logits().unwrap());
            assert_eq!(tok, argmax(sr.last_logits().unwrap()), "{v:?}: prefill argmax");
            for step in 0..8 {
                let lp = sp.decode_one(tok).unwrap().to_vec();
                let lr = sr.decode_one(tok).unwrap();
                assert_eq!(argmax(&lp), argmax(lr), "{v:?}: step {step} argmax");
                tok = argmax(&lp);
            }
        }
    }
    #[cfg(not(feature = "pjrt"))]
    eprintln!("skipping: built without the `pjrt` cargo feature (PJRT-only path)");
}
