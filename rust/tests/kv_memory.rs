//! Unified KV memory management invariants (the bit-determinism contract
//! behind preemption and chunked prefill): committed KV rows are a pure
//! function of the token prefix, so (a) feeding a prompt in chunks of any
//! size yields byte-identical generations, and (b) swapping a run's KV
//! out to host memory and back mid-generation changes nothing.
//!
//! Hermetic: runs on the pure-Rust reference backend when no artifacts
//! exist, same as the other integration tests.

use cas_spec::engine::{build_engine, EngineOpts, RoundPhase, ENGINES};
use cas_spec::model::Variant;
use cas_spec::runtime::Runtime;
use cas_spec::spec::SamplingParams;
use cas_spec::workload::{Language, Suite};

fn open_runtime() -> Runtime {
    Runtime::open(&Runtime::default_dir()).expect("runtime open")
}

fn bench_prompt(rt: &Runtime, max_new: usize) -> Vec<u32> {
    let lang = Language::build(rt.manifest.lang_seed);
    let suite = Suite::spec_bench(&lang, 7, 1, max_new);
    suite.items[0].prompt.clone()
}

#[test]
fn chunked_prefill_is_byte_identical_across_engines() {
    // Every engine, monolithic vs chunk=3: same tokens, same stats-visible
    // output. Chunk 3 never divides the prompt evenly, so the tail chunk
    // and the "run is created but prefill is pending" states are exercised.
    let rt = open_runtime();
    let srt = rt.load_scale("small", &Variant::ALL).expect("load small");
    let prompt = bench_prompt(&rt, 20);
    for name in ENGINES {
        let mut mono = build_engine(name, &srt, &EngineOpts::default()).expect("engine");
        let mut opts = EngineOpts::default();
        opts.prefill_chunk = 3;
        let mut chunked = build_engine(name, &srt, &opts).expect("engine");
        let a = mono.generate(&prompt, 20).expect("monolithic generate");
        let b = chunked.generate(&prompt, 20).expect("chunked generate");
        assert_eq!(a.tokens, b.tokens, "{name}: chunked prefill changed the output");
        assert!(!a.tokens.is_empty(), "{name}: empty generation");
    }
}

#[test]
fn chunk_size_sweep_is_byte_identical() {
    // Representative engines (target-only, static cascade, adaptive DyTC)
    // across chunk sizes: 1 (every token a chunk), a non-divisor, and one
    // larger than the prompt (degenerates to monolithic). Greedy + sampled.
    let rt = open_runtime();
    let srt = rt.load_scale("small", &Variant::ALL).expect("load small");
    let prompt = bench_prompt(&rt, 16);
    let sp = SamplingParams { temperature: 0.8, top_p: 0.9, seed: 77 };
    for name in ["ar", "pld", "vchc", "cas-spec"] {
        let mut mono = build_engine(name, &srt, &EngineOpts::default()).expect("engine");
        let base_g = mono.generate(&prompt, 16).expect("generate").tokens;
        let base_s =
            mono.generate_sampled(&prompt, 16, Some(sp)).expect("generate").tokens;
        for chunk in [1usize, 5, 4096] {
            let mut opts = EngineOpts::default();
            opts.prefill_chunk = chunk;
            let mut eng = build_engine(name, &srt, &opts).expect("engine");
            let g = eng.generate(&prompt, 16).expect("generate").tokens;
            assert_eq!(base_g, g, "{name} chunk={chunk}: greedy output changed");
            let s = eng.generate_sampled(&prompt, 16, Some(sp)).expect("generate").tokens;
            assert_eq!(base_s, s, "{name} chunk={chunk}: sampled output changed");
        }
    }
}

#[test]
fn suspend_resume_mid_generation_is_byte_identical() {
    // Preemption losslessness at the engine layer: swap a run's KV out to
    // host memory and back between rounds; the remaining rounds must emit
    // exactly what an unpreempted run emits.
    let rt = open_runtime();
    let srt = rt.load_scale("small", &Variant::ALL).expect("load small");
    let prompt = bench_prompt(&rt, 24);
    for name in ["pld", "swift", "cas-spec"] {
        let mut eng = build_engine(name, &srt, &EngineOpts::default()).expect("engine");
        let base = eng.generate(&prompt, 24).expect("generate").tokens;

        let swaps_before = srt.kv_pool().stats().swaps_out;
        let mut run = eng.begin(&prompt, 24).expect("begin");
        let mut rounds = 0usize;
        while !run.is_done() {
            run.round().expect("round");
            rounds += 1;
            if !run.is_done() && (rounds == 2 || rounds == 4) {
                run.suspend().unwrap_or_else(|e| panic!("{name}: suspend: {e:#}"));
                assert!(run.is_suspended(), "{name}: not suspended after suspend");
                // idempotent: a second suspend must not double-snapshot
                run.suspend().expect("re-suspend");
                run.resume().unwrap_or_else(|e| panic!("{name}: resume: {e:#}"));
                assert!(!run.is_suspended(), "{name}: still suspended after resume");
            }
        }
        let out = run.finish().tokens;
        assert_eq!(base, out, "{name}: suspend/resume changed the output");
        assert!(
            srt.kv_pool().stats().swaps_out > swaps_before,
            "{name}: no swap_out recorded on the pool"
        );
    }
}

#[test]
fn suspend_releases_pool_bytes_and_resume_reacquires_them() {
    let rt = open_runtime();
    let srt = rt.load_scale("small", &Variant::ALL).expect("load small");
    let prompt = bench_prompt(&rt, 12);
    let eng = build_engine("pld", &srt, &EngineOpts::default()).expect("engine");

    let idle = srt.kv_pool().stats().used();
    let mut run = eng.begin(&prompt, 12).expect("begin");
    run.round().expect("round");
    let live = srt.kv_pool().stats().used();
    assert!(live > idle, "running session holds no pool bytes");

    run.suspend().expect("suspend");
    let parked = srt.kv_pool().stats();
    assert_eq!(parked.used(), idle, "suspend did not release the session's bytes");
    assert!(parked.swap_bytes > 0, "suspended KV not accounted as swap bytes");

    run.resume().expect("resume");
    let resumed = srt.kv_pool().stats();
    assert_eq!(resumed.used(), live, "resume did not re-reserve the same bytes");
    assert_eq!(resumed.swap_bytes, 0, "swap bytes not drained after resume");

    while !run.is_done() {
        run.round().expect("round");
    }
    drop(run.finish());
    assert_eq!(srt.kv_pool().stats().used(), idle, "leases leaked after finish");
}

#[test]
fn chunked_prefill_rounds_report_pending_phase() {
    // The poll-style scheduler contract: while prefill is pending, each
    // begin_round consumes one chunk, emits nothing, and keeps the run
    // alive; the first emitted token appears only once the prompt is fed.
    let rt = open_runtime();
    let srt = rt.load_scale("small", &Variant::ALL).expect("load small");
    let prompt = bench_prompt(&rt, 8);
    let mut opts = EngineOpts::default();
    opts.prefill_chunk = 2;
    let eng = build_engine("pld", &srt, &opts).expect("engine");
    let mut run = eng.begin(&prompt, 8).expect("begin");

    // prompt.len() tokens at 2/chunk: the first ceil(len/2) - 1 polls feed
    // chunks and emit nothing; the poll that feeds the last chunk emits
    // the first decoded token.
    let mut emitted_total = 0usize;
    let mut chunk_polls = 0usize;
    while emitted_total == 0 {
        match run.begin_round().expect("begin_round") {
            RoundPhase::Done(o) => {
                assert!(!o.done, "run finished during prefill");
                emitted_total += o.emitted.len();
                if o.emitted.is_empty() {
                    chunk_polls += 1;
                }
            }
            RoundPhase::Pending { .. } => panic!("pending step during prefill"),
        }
        assert!(chunk_polls <= prompt.len(), "prefill never completed");
    }
    assert_eq!(chunk_polls, prompt.len().div_ceil(2) - 1, "wrong chunk count");
    assert_eq!(emitted_total, 1, "prefill completion must emit exactly one token");
}
